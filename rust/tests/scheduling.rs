//! Integration: the event-driven serving API — legacy bit-match, arrival
//! gating, batching, scheduling policies, determinism, stats, a
//! randomized fuzz harness over policies x prefill modes x batch widths,
//! and the adapter-affinity starvation bound.

mod common;

use common::{exp_1b, server_1b};
use primal::config::{ExperimentConfig, PolicyKind};
use primal::coordinator::{
    AdapterId, Fcfs, FunctionalMode, Request, RequestResult, SchedCounters, Server,
    ServerBuilder, ServerConfig, ServerStats, ShortestJobFirst, TokenEvent,
};
use primal::dataflow::{prefill_program, reprogram_program};
use primal::sim::{program_cost, LayerCostModel, Simulator};
use primal::util::Rng;

/// Independent reference for the paper's serial batch-1 FCFS model,
/// computed straight from the sim primitives with the legacy server's
/// exact arithmetic (reprogram + layer-sequential prefill template +
/// token-by-token decode). Returns (ttft_s, itl_ms, total_s) per request.
fn serial_reference(cfg: &ExperimentConfig, trace: &[(usize, usize, u32)]) -> Vec<(f64, f64, f64)> {
    let sim = Simulator::new(cfg);
    let lm0 = &sim.mapping().layers[0];
    let cyc = cfg.system.cycle_s();
    let n_layers = cfg.model.layers;

    let reprog = program_cost(&reprogram_program(cfg, lm0), &cfg.system, &cfg.calib);
    let reprog_s = if cfg.srpg {
        reprog.cycles as f64 * cyc
    } else {
        (reprog.cycles * n_layers as u64) as f64 * cyc
    };

    let block = 128usize.min(cfg.input_tokens.max(1));
    let n_blocks = cfg.input_tokens.div_ceil(block);
    let mut block_s = Vec::new();
    for b in 0..n_blocks {
        let this_block = if b + 1 == n_blocks {
            cfg.input_tokens - b * block
        } else {
            block
        };
        let kv = (b * block + this_block / 2).max(1);
        let c = program_cost(
            &prefill_program(cfg, lm0, this_block, kv),
            &cfg.system,
            &cfg.calib,
        );
        block_s.push(c.cycles as f64 * cyc);
    }

    let model = LayerCostModel::build(cfg, lm0);
    let mut resident: Option<u32> = None;
    let mut out = Vec::new();
    for &(input, output, adapter) in trace {
        let swap = resident != Some(adapter);
        resident = Some(adapter);
        let mut ttft = if swap { reprog_s } else { 0.0 };
        let prefill_per_layer: f64 = if input == cfg.input_tokens {
            block_s.iter().sum()
        } else {
            let per_tok: f64 = block_s.iter().sum::<f64>() / cfg.input_tokens as f64;
            per_tok * input as f64
        };
        ttft += prefill_per_layer * n_layers as f64;
        // Decode accumulates in integer cycles (the server's accounting):
        // the f64 conversion happens once per request, so step-by-step
        // and fast-forwarded serving both bit-match this reference.
        let mut decode_cycles = 0u64;
        for i in 0..output {
            let kv = input + i;
            decode_cycles += model.eval(kv).cycles * n_layers as u64;
        }
        let decode = decode_cycles as f64 * cyc;
        out.push((ttft, decode / output as f64 * 1e3, ttft + decode));
    }
    out
}

#[test]
fn batch1_fcfs_bitmatches_serial_reference() {
    let trace = [(256usize, 32usize, 0u32), (256, 32, 0), (256, 16, 1), (128, 8, 0)];
    let mut s = server_1b(256, 1, PolicyKind::Fcfs, 2);
    for (i, &(input, output, a)) in trace.iter().enumerate() {
        s.submit(Request::new(i as u64, AdapterId(a), input, output)).unwrap();
    }
    let results = s.drain(None).unwrap();
    let expect = serial_reference(&exp_1b(256), &trace);
    assert_eq!(results.len(), expect.len());
    for (r, &(ttft, itl, total)) in results.iter().zip(&expect) {
        assert_eq!(r.ttft_s.to_bits(), ttft.to_bits(), "ttft of {}", r.request);
        assert_eq!(r.itl_ms.to_bits(), itl.to_bits(), "itl of {}", r.request);
        assert_eq!(r.total_s.to_bits(), total.to_bits(), "total of {}", r.request);
        assert_eq!(r.stall_s, 0.0, "batch 1 never stalls");
    }
    // The serial clock is the running sum of service times.
    let total: f64 = expect.iter().map(|e| e.2).sum();
    assert!((s.stats().sim_time_s - total).abs() < 1e-9);
}

#[test]
fn builder_default_equals_legacy_shim() {
    let run = |mut s: Server| -> Vec<RequestResult> {
        s.register_adapter(AdapterId(0));
        s.register_adapter(AdapterId(1));
        for (i, a) in [(0u64, 0u32), (1, 1), (2, 1), (3, 0)] {
            s.submit(Request::new(i, AdapterId(a), 256, 16)).unwrap();
        }
        s.drain(None).unwrap()
    };
    let via_builder = run(ServerBuilder::default().max_batch(1).policy(Fcfs).build().unwrap());
    let via_legacy = run(Server::new(ServerConfig {
        experiment: exp_1b(256),
        functional: FunctionalMode::TimingOnly,
        artifacts_dir: "artifacts".into(),
    })
    .unwrap());
    assert_eq!(via_builder.len(), via_legacy.len());
    for (a, b) in via_builder.iter().zip(&via_legacy) {
        assert_eq!(a.request, b.request);
        assert_eq!(a.swap, b.swap);
        assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits());
        assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits());
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
    }
}

#[test]
fn event_loop_is_deterministic() {
    let run = || {
        let mut s = server_1b(256, 4, PolicyKind::AdapterAffinity, 3);
        for i in 0..9u64 {
            let a = (i % 3) as u32;
            s.submit(Request::new(i, AdapterId(a), 256, 8).at(i as f64 * 0.01)).unwrap();
        }
        let results = s.drain(None).unwrap();
        let stats = s.stats();
        (results, stats)
    };
    let (r1, s1) = run();
    let (r2, s2) = run();
    assert_eq!(r1.len(), r2.len());
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.request, b.request);
        assert_eq!(a.swap, b.swap);
        assert_eq!(a.start_s.to_bits(), b.start_s.to_bits());
        assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits());
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
    }
    assert_eq!(s1.adapter_swaps, s2.adapter_swaps);
    assert_eq!(s1.sim_time_s.to_bits(), s2.sim_time_s.to_bits());
}

#[test]
fn adapter_affinity_cuts_swaps_and_beats_fcfs_throughput() {
    // Round-robin adapters: the worst case for strict FCFS (every
    // admission is a task switch, and head-of-line mismatches keep the
    // batch at width 1), the best case for affinity grouping.
    let run = |policy: PolicyKind| {
        let mut s = server_1b(256, 4, policy, 4);
        for i in 0..16u64 {
            s.submit(Request::new(i, AdapterId((i % 4) as u32), 256, 16)).unwrap();
        }
        let results = s.drain(None).unwrap();
        assert_eq!(results.len(), 16);
        let st = s.stats();
        (st.adapter_swaps, st.total_tokens as f64 / st.sim_time_s)
    };
    let (fcfs_swaps, fcfs_tps) = run(PolicyKind::Fcfs);
    let (aff_swaps, aff_tps) = run(PolicyKind::AdapterAffinity);
    assert!(
        aff_swaps < fcfs_swaps,
        "affinity must strictly reduce swaps: {aff_swaps} vs {fcfs_swaps}"
    );
    assert!(
        aff_tps > fcfs_tps,
        "affinity must beat FCFS throughput: {aff_tps:.2} vs {fcfs_tps:.2} tok/s"
    );
    // On this trace the bounds are exact: one swap per adapter group vs
    // one per request.
    assert_eq!(aff_swaps, 4);
    assert_eq!(fcfs_swaps, 16);
}

#[test]
fn batched_decode_outpaces_serial_on_one_adapter() {
    let run = |max_batch: usize| {
        let mut s = server_1b(256, max_batch, PolicyKind::Fcfs, 1);
        for i in 0..6u64 {
            s.submit(Request::new(i, AdapterId(0), 256, 16)).unwrap();
        }
        let results = s.drain(None).unwrap();
        assert_eq!(results.len(), 6);
        s.stats()
    };
    let serial = run(1);
    let batched = run(4);
    assert_eq!(serial.total_tokens, batched.total_tokens);
    assert!(
        batched.sim_time_s < serial.sim_time_s,
        "pipelined batch {} s must beat serial {} s",
        batched.sim_time_s,
        serial.sim_time_s
    );
    assert_eq!(batched.max_batch_observed, 4);
    assert_eq!(serial.max_batch_observed, 1);
}

#[test]
fn queue_delay_is_start_minus_arrival() {
    // Learn the service time of one request, then arrive a second one
    // mid-service: its wait must be exactly start - arrival.
    let mut probe = server_1b(256, 1, PolicyKind::Fcfs, 1);
    probe.submit(Request::new(0, AdapterId(0), 256, 16)).unwrap();
    let t0 = probe.drain(None).unwrap()[0].total_s;

    let mut s = server_1b(256, 1, PolicyKind::Fcfs, 1);
    s.submit(Request::new(0, AdapterId(0), 256, 16)).unwrap();
    s.submit(Request::new(1, AdapterId(0), 256, 16).at(t0 * 0.5)).unwrap();
    let results = s.drain(None).unwrap();
    assert_eq!(results[0].queue_s, 0.0, "first request never waits");
    let r1 = &results[1];
    assert_eq!(r1.queue_s.to_bits(), (r1.start_s - r1.arrival_s).to_bits());
    assert!(r1.queue_s > 0.0, "mid-service arrival must wait");
    assert!(r1.start_s >= t0 * 0.99, "r1 starts when r0 finishes");
    // Late arrival into an idle server: no wait at all.
    let mut idle = server_1b(256, 1, PolicyKind::Fcfs, 1);
    idle.submit(Request::new(0, AdapterId(0), 256, 8).at(123.0)).unwrap();
    let r = idle.drain(None).unwrap();
    assert_eq!(r[0].start_s, 123.0);
    assert_eq!(r[0].queue_s, 0.0);
}

#[test]
fn sjf_serves_shortest_jobs_first() {
    let mut s = server_1b(256, 1, PolicyKind::ShortestJobFirst, 1);
    for (i, out) in [(0u64, 32usize), (1, 4), (2, 16)] {
        s.submit(Request::new(i, AdapterId(0), 256, out)).unwrap();
    }
    let order: Vec<u64> = s.drain(None).unwrap().iter().map(|r| r.request).collect();
    assert_eq!(order, vec![1, 2, 0]);
    // The policy object route builds the same schedule.
    let mut s2 = ServerBuilder::from_experiment(exp_1b(256))
        .policy(ShortestJobFirst)
        .build()
        .unwrap();
    s2.register_adapter(AdapterId(0));
    for (i, out) in [(0u64, 32usize), (1, 4), (2, 16)] {
        s2.submit(Request::new(i, AdapterId(0), 256, out)).unwrap();
    }
    let order2: Vec<u64> = s2.drain(None).unwrap().iter().map(|r| r.request).collect();
    assert_eq!(order2, vec![1, 2, 0]);
}

#[test]
fn incremental_runs_report_true_means() {
    // The legacy accumulator divided already-averaged values on a second
    // run(); means must now be exact over all served requests.
    let mut s = server_1b(256, 1, PolicyKind::Fcfs, 2);
    s.submit(Request::new(0, AdapterId(0), 256, 16)).unwrap();
    let first = s.run(None).unwrap();
    s.submit(Request::new(1, AdapterId(1), 256, 16)).unwrap();
    s.submit(Request::new(2, AdapterId(1), 256, 16)).unwrap();
    let second = s.run(None).unwrap();
    let all: Vec<&RequestResult> = first.iter().chain(second.iter()).collect();
    assert_eq!(all.len(), 3);
    let st = s.stats();
    assert_eq!(st.served, 3);
    let mean_ttft: f64 = all.iter().map(|r| r.ttft_s).sum::<f64>() / 3.0;
    let mean_itl: f64 = all.iter().map(|r| r.itl_ms).sum::<f64>() / 3.0;
    assert!((st.mean_ttft_s - mean_ttft).abs() < 1e-12, "running-sum mean");
    assert!((st.mean_itl_ms - mean_itl).abs() < 1e-9, "running-sum mean");
    // And reading stats twice must not re-divide.
    let again = s.stats();
    assert_eq!(again.mean_ttft_s.to_bits(), st.mean_ttft_s.to_bits());
}

#[test]
fn percentiles_are_ordered() {
    let mut s = server_1b(256, 2, PolicyKind::Fcfs, 2);
    for i in 0..8u64 {
        let a = (i % 2) as u32;
        s.submit(Request::new(i, AdapterId(a), 256, 8 + 4 * i as usize)).unwrap();
    }
    s.drain(None).unwrap();
    let st = s.stats();
    for lat in [st.ttft, st.itl, st.queue] {
        assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99, "{lat:?}");
    }
    assert!(st.ttft.p50 > 0.0);
    assert!(st.itl.mean > 0.0);
    assert!(st.itl.p99 >= st.itl.mean * 0.5);
}

#[test]
fn run_until_partitions_work_at_the_deadline() {
    let far = 1.0e6;
    let mut s = server_1b(256, 1, PolicyKind::Fcfs, 1);
    s.submit(Request::new(0, AdapterId(0), 256, 8)).unwrap();
    s.submit(Request::new(1, AdapterId(0), 256, 8).at(far)).unwrap();
    let early = s.run_until(far / 2.0, None).unwrap();
    assert_eq!(early.len(), 1);
    assert_eq!(early[0].request, 0);
    assert_eq!(s.pending(), 1);
    assert_eq!(s.now_s(), far / 2.0, "idle clock advances to the deadline");
    let late = s.drain(None).unwrap();
    assert_eq!(late.len(), 1);
    assert_eq!(late[0].request, 1);
    assert!(late[0].start_s >= far);
    assert_eq!(late[0].queue_s, 0.0);
}

// ---- randomized scheduling fuzz harness ----------------------------------

const FUZZ_ADAPTERS: u32 = 3;
const FUZZ_REQUESTS: usize = 12;

/// Seeded trace: mixed adapters, Poisson-ish arrivals, mixed prompt and
/// output lengths (exercising both chunk-schedule branches).
fn fuzz_trace(seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..FUZZ_REQUESTS as u64)
        .map(|i| {
            t += rng.f64() * 0.05;
            let adapter = AdapterId(rng.range(0, FUZZ_ADAPTERS as usize) as u32);
            let input = 64 + rng.range(0, 256);
            let output = 4 + rng.range(0, 20);
            Request::new(i, adapter, input, output).at(t)
        })
        .collect()
}

fn fuzz_run(
    seed: u64,
    policy: PolicyKind,
    batch: usize,
    chunk: Option<usize>,
) -> (Vec<RequestResult>, Vec<TokenEvent>, f64, u64, u64) {
    fuzz_run_sharded(seed, policy, batch, chunk, 1)
}

fn fuzz_run_sharded(
    seed: u64,
    policy: PolicyKind,
    batch: usize,
    chunk: Option<usize>,
    chips: usize,
) -> (Vec<RequestResult>, Vec<TokenEvent>, f64, u64, u64) {
    fuzz_run_full(seed, policy, batch, chunk, chips, true)
}

fn fuzz_run_full(
    seed: u64,
    policy: PolicyKind,
    batch: usize,
    chunk: Option<usize>,
    chips: usize,
    fast_forward: bool,
) -> (Vec<RequestResult>, Vec<TokenEvent>, f64, u64, u64) {
    let mut exp = exp_1b(256);
    exp.shard.n_chips = chips;
    let mut s = ServerBuilder::from_experiment(exp)
        .max_batch(batch)
        .policy_kind(policy)
        .prefill_chunk(chunk)
        .decode_fast_forward(fast_forward)
        .build()
        .expect("server");
    for a in 0..FUZZ_ADAPTERS {
        s.register_adapter(AdapterId(a));
    }
    for r in fuzz_trace(seed) {
        s.submit(r).unwrap();
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let results = s.drain(Some(&tx)).unwrap();
    drop(tx);
    let events: Vec<TokenEvent> = rx.iter().collect();
    let st = s.stats();
    (results, events, st.sim_time_s, st.adapter_swaps, st.adapter_hits)
}

fn check_invariants(
    label: &str,
    results: &[RequestResult],
    events: &[TokenEvent],
    swaps: u64,
    hits: u64,
) {
    // Completed-request conservation: every submitted id retires once.
    assert_eq!(results.len(), FUZZ_REQUESTS, "{label}: conservation");
    let mut ids: Vec<u64> = results.iter().map(|r| r.request).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..FUZZ_REQUESTS as u64).collect::<Vec<_>>(), "{label}: ids");

    for r in results {
        assert!(r.start_s >= r.arrival_s, "{label}: {} started early", r.request);
        assert_eq!(
            r.queue_s.to_bits(),
            (r.start_s - r.arrival_s).to_bits(),
            "{label}: queue identity of {}",
            r.request
        );
        assert!(r.ttft_s > 0.0 && r.stall_s >= 0.0, "{label}: {}", r.request);
        assert!(r.total_s >= r.ttft_s, "{label}: {} total < ttft", r.request);
    }

    // Token-stream sanity: per request, `output_tokens` strictly
    // monotone events, none before arrival + TTFT (event times are
    // relative to admission, so absolute time is start_s + at_s).
    for r in results {
        let times: Vec<f64> = events
            .iter()
            .filter(|e| e.request == r.request)
            .map(|e| e.at_s)
            .collect();
        assert_eq!(times.len(), r.tokens_out, "{label}: stream of {}", r.request);
        assert!(
            times.windows(2).all(|w| w[1] > w[0]),
            "{label}: stream of {} not monotone",
            r.request
        );
        let first_abs = r.start_s + times[0];
        assert!(
            first_abs >= r.arrival_s + r.ttft_s,
            "{label}: {} emitted a token before arrival + ttft",
            r.request
        );
    }

    // Adapter accounting: every admission is exactly one swap or hit, and
    // per-adapter swaps never exceed that adapter's admissions.
    assert_eq!(swaps + hits, FUZZ_REQUESTS as u64, "{label}: swap/hit total");
    assert!(swaps >= 1, "{label}: the cold start must swap");
}

#[test]
fn randomized_traces_hold_invariants_for_all_modes() {
    for seed in [1u64, 7, 42] {
        for &(batch, chunk) in &[(1usize, None), (1, Some(128)), (4, None), (4, Some(128))] {
            for policy in [
                PolicyKind::Fcfs,
                PolicyKind::AdapterAffinity,
                PolicyKind::ShortestJobFirst,
            ] {
                let label = format!(
                    "seed {seed} / {} / batch {batch} / chunk {chunk:?}",
                    policy.name()
                );
                let (results, events, sim_t, swaps, hits) =
                    fuzz_run(seed, policy, batch, chunk);
                check_invariants(&label, &results, &events, swaps, hits);

                // Identical replay determinism, bit for bit.
                let (r2, _, t2, s2, h2) = fuzz_run(seed, policy, batch, chunk);
                assert_eq!(sim_t.to_bits(), t2.to_bits(), "{label}: clock replay");
                assert_eq!((swaps, hits), (s2, h2), "{label}: swap replay");
                for (a, b) in results.iter().zip(&r2) {
                    assert_eq!(a.request, b.request, "{label}: order replay");
                    assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
                    assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
                    assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
                }
            }
        }
    }
}

#[test]
fn randomized_traces_hold_invariants_when_sharded() {
    // The sharded axis of the fuzz harness: chips {1, 2, 4} x batch
    // {1, 4} over every policy, with the same seeded bitwise-replay
    // determinism as the single-chip sweep (chunked prefill is covered
    // per-chip-count at batch 4, where admissions actually interleave).
    let seed = 7u64;
    for &chips in &[1usize, 2, 4] {
        for &(batch, chunk) in &[(1usize, None), (4usize, Some(128))] {
            for policy in [
                PolicyKind::Fcfs,
                PolicyKind::AdapterAffinity,
                PolicyKind::ShortestJobFirst,
            ] {
                let label = format!(
                    "chips {chips} / {} / batch {batch} / chunk {chunk:?}",
                    policy.name()
                );
                let (results, events, sim_t, swaps, hits) =
                    fuzz_run_sharded(seed, policy, batch, chunk, chips);
                check_invariants(&label, &results, &events, swaps, hits);

                // Bitwise replay determinism on the sharded axis.
                let (r2, _, t2, s2, h2) = fuzz_run_sharded(seed, policy, batch, chunk, chips);
                assert_eq!(sim_t.to_bits(), t2.to_bits(), "{label}: clock replay");
                assert_eq!((swaps, hits), (s2, h2), "{label}: swap replay");
                for (a, b) in results.iter().zip(&r2) {
                    assert_eq!(a.request, b.request, "{label}: order replay");
                    assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
                    assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
                    assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
                }
            }
        }
    }
}

#[test]
fn fast_forward_bitmatches_stepwise_on_fuzz_traces() {
    // The closed-form decode fast-forward must be invisible: completion
    // records, token streams, clock, and swap accounting all bit-identical
    // to the step-by-step path, across policies x batch x chunk x chips.
    for seed in [1u64, 7, 42] {
        for &(batch, chunk, chips) in &[
            (1usize, None, 1usize),
            (4, None, 1),
            (4, Some(128), 1),
            (4, None, 2),
            (1, None, 4),
        ] {
            for policy in [
                PolicyKind::Fcfs,
                PolicyKind::AdapterAffinity,
                PolicyKind::ShortestJobFirst,
            ] {
                let label = format!(
                    "seed {seed} / {} / batch {batch} / chunk {chunk:?} / chips {chips}",
                    policy.name()
                );
                let (rf, ef, tf, sf, hf) =
                    fuzz_run_full(seed, policy, batch, chunk, chips, true);
                let (rs, es, ts, ss, hs) =
                    fuzz_run_full(seed, policy, batch, chunk, chips, false);
                assert_eq!(tf.to_bits(), ts.to_bits(), "{label}: clock");
                assert_eq!((sf, hf), (ss, hs), "{label}: swaps/hits");
                assert_eq!(rf.len(), rs.len(), "{label}: completions");
                for (a, b) in rf.iter().zip(&rs) {
                    assert_eq!(a.request, b.request, "{label}: order");
                    assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
                    assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
                    assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
                    assert_eq!(a.stall_s.to_bits(), b.stall_s.to_bits(), "{label}");
                    assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
                }
                assert_eq!(ef.len(), es.len(), "{label}: token events");
                for (a, b) in ef.iter().zip(&es) {
                    assert_eq!(a.request, b.request, "{label}: token order");
                    assert_eq!(a.index, b.index, "{label}: token index");
                    assert_eq!(a.at_s.to_bits(), b.at_s.to_bits(), "{label}: token time");
                }
            }
        }
    }
}

#[test]
fn fast_forward_bitmatches_stepwise_under_affinity_run_bound() {
    // The starvation-bounded affinity policy is stateful (run-length
    // counter): a discarded fast-forward admission probe must not advance
    // it, so the bound fires at the same admissions either way.
    for batch in [1usize, 4] {
        for mrl in [1usize, 2, 3] {
            let run = |ff: bool| {
                let mut exp = exp_1b(256);
                exp.serving.affinity_max_run_len = Some(mrl);
                let mut s = ServerBuilder::from_experiment(exp)
                    .max_batch(batch)
                    .policy_kind(PolicyKind::AdapterAffinity)
                    .decode_fast_forward(ff)
                    .build()
                    .unwrap();
                s.register_adapter(AdapterId(0));
                s.register_adapter(AdapterId(1));
                for i in 0..6u64 {
                    s.submit(Request::new(i, AdapterId(0), 256, 30)).unwrap();
                }
                s.submit(Request::new(6, AdapterId(1), 256, 30)).unwrap();
                s.submit(Request::new(7, AdapterId(1), 256, 30).at(0.05)).unwrap();
                let results = s.drain(None).unwrap();
                (results, s.stats())
            };
            let (rf, sf) = run(true);
            let (rs, ss) = run(false);
            let label = format!("b{batch} mrl{mrl}");
            assert_eq!(rf.len(), rs.len(), "{label}");
            for (a, b) in rf.iter().zip(&rs) {
                assert_eq!(a.request, b.request, "{label}: admission order");
                assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
                assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
            }
            assert_eq!(sf.sim_time_s.to_bits(), ss.sim_time_s.to_bits(), "{label}");
            assert_eq!(sf.adapter_swaps, ss.adapter_swaps, "{label}: swaps");
        }
    }
}

#[test]
fn fast_forward_bitmatches_stepwise_stats() {
    // Gap-sample (per-token ITL) statistics are part of the contract too.
    let run = |ff: bool| {
        let mut s = ServerBuilder::from_experiment(exp_1b(256))
            .max_batch(4)
            .policy_kind(PolicyKind::Fcfs)
            .decode_fast_forward(ff)
            .build()
            .unwrap();
        for a in 0..FUZZ_ADAPTERS {
            s.register_adapter(AdapterId(a));
        }
        for r in fuzz_trace(7) {
            s.submit(r).unwrap();
        }
        s.drain(None).unwrap();
        s.stats()
    };
    let f = run(true);
    let s = run(false);
    assert_eq!(f.itl.mean.to_bits(), s.itl.mean.to_bits());
    assert_eq!(f.itl.p50.to_bits(), s.itl.p50.to_bits());
    assert_eq!(f.itl.p95.to_bits(), s.itl.p95.to_bits());
    assert_eq!(f.itl.p99.to_bits(), s.itl.p99.to_bits());
    assert_eq!(f.mean_itl_ms.to_bits(), s.mean_itl_ms.to_bits());
    assert_eq!(f.mean_ttft_s.to_bits(), s.mean_ttft_s.to_bits());
    assert_eq!(f.sim_time_s.to_bits(), s.sim_time_s.to_bits());
    assert_eq!(f.total_tokens, s.total_tokens);
}

#[test]
fn run_until_fast_forward_respects_the_deadline() {
    // Fast-forwarded run_until must partition work at the deadline the
    // same way stepwise execution does — including the final event that
    // carries the clock past t.
    let mk = |ff: bool| {
        let mut s = ServerBuilder::from_experiment(exp_1b(256))
            .max_batch(2)
            .decode_fast_forward(ff)
            .build()
            .unwrap();
        s.register_adapter(AdapterId(0));
        for i in 0..4u64 {
            s.submit(Request::new(i, AdapterId(0), 256, 24).at(i as f64 * 0.002)).unwrap();
        }
        s
    };
    let mut a = mk(true);
    let mut b = mk(false);
    // Walk both servers through the same ladder of deadlines.
    for t in [0.001f64, 0.05, 0.2, 1.0, 50.0] {
        let ra = a.run_until(t, None).unwrap();
        let rb = b.run_until(t, None).unwrap();
        assert_eq!(a.now_s().to_bits(), b.now_s().to_bits(), "clock at t={t}");
        assert_eq!(ra.len(), rb.len(), "completions at t={t}");
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.request, y.request);
            assert_eq!(x.total_s.to_bits(), y.total_s.to_bits());
        }
        assert_eq!(a.pending(), b.pending(), "pending at t={t}");
        assert_eq!(a.in_flight(), b.in_flight(), "in flight at t={t}");
    }
    let ra = a.drain(None).unwrap();
    let rb = b.drain(None).unwrap();
    assert_eq!(ra.len(), rb.len());
}

#[test]
fn one_chip_fuzz_bitmatches_the_unsharded_server() {
    // chips = 1 through the sharded constructor must be indistinguishable
    // from the default single-chip server, bit for bit.
    for policy in [PolicyKind::Fcfs, PolicyKind::AdapterAffinity] {
        let (a, _, ta, sa, ha) = fuzz_run(42, policy, 4, Some(128));
        let (b, _, tb, sb, hb) = fuzz_run_sharded(42, policy, 4, Some(128), 1);
        assert_eq!(ta.to_bits(), tb.to_bits(), "{}: clock", policy.name());
        assert_eq!((sa, ha), (sb, hb));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request, y.request);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.total_s.to_bits(), y.total_s.to_bits());
        }
    }
}

#[test]
fn sharded_server_serves_the_same_trace_faster() {
    // Per-layer decode and prefill both shrink under sharding, so the
    // same fuzz trace must drain in strictly less simulated time; the
    // completion set is conserved.
    for &(batch, chunk) in &[(1usize, None), (4usize, Some(128))] {
        let (r1, _, t1, _, _) = fuzz_run_sharded(1, PolicyKind::Fcfs, batch, chunk, 1);
        let (r2, _, t2, _, _) = fuzz_run_sharded(1, PolicyKind::Fcfs, batch, chunk, 2);
        assert_eq!(r1.len(), r2.len());
        assert!(
            t2 < t1,
            "batch {batch}: 2-chip drain {t2} s must beat single-chip {t1} s"
        );
    }
}

#[test]
fn per_adapter_swaps_bounded_by_admissions() {
    let (results, _, _, _, _) = fuzz_run(7, PolicyKind::AdapterAffinity, 4, Some(128));
    let mut served: std::collections::BTreeMap<u32, u64> = Default::default();
    for r in &results {
        *served.entry(r.adapter.0).or_default() += 1;
    }
    let mut s = ServerBuilder::from_experiment(exp_1b(256))
        .max_batch(4)
        .policy_kind(PolicyKind::AdapterAffinity)
        .prefill_chunk(Some(128))
        .build()
        .unwrap();
    for a in 0..FUZZ_ADAPTERS {
        s.register_adapter(AdapterId(a));
    }
    for r in fuzz_trace(7) {
        s.submit(r).unwrap();
    }
    s.drain(None).unwrap();
    for (id, u) in &s.stats().per_adapter {
        let n = served.get(&id.0).copied().unwrap_or(0);
        assert_eq!(u.served, n, "adapter {id:?}");
        assert!(u.swaps <= n, "adapter {id:?}: swaps {} > admissions {n}", u.swaps);
        assert_eq!(u.swaps + u.hits, n, "adapter {id:?}: swap/hit partition");
    }
}

#[test]
fn affinity_starvation_bound_limits_minority_queue_delay() {
    // Eight majority-adapter requests and one minority request, all at
    // t=0: unbounded affinity serves the minority dead last; a run bound
    // of 2 forces a regroup after two majority admissions.
    let run = |max_run_len: Option<usize>| {
        let mut exp = exp_1b(256);
        exp.serving.affinity_max_run_len = max_run_len;
        let mut s = ServerBuilder::from_experiment(exp)
            .max_batch(1)
            .policy_kind(PolicyKind::AdapterAffinity)
            .build()
            .unwrap();
        s.register_adapter(AdapterId(0));
        s.register_adapter(AdapterId(1));
        for i in 0..8u64 {
            s.submit(Request::new(i, AdapterId(0), 256, 8)).unwrap();
        }
        s.submit(Request::new(8, AdapterId(1), 256, 8)).unwrap();
        let res = s.drain(None).unwrap();
        assert_eq!(res.len(), 9);
        let pos = res.iter().position(|r| r.request == 8).unwrap();
        let queue = res.iter().find(|r| r.request == 8).unwrap().queue_s;
        (pos, queue)
    };
    let (pos_unbounded, q_unbounded) = run(None);
    let (pos_bounded, q_bounded) = run(Some(2));
    assert_eq!(pos_unbounded, 8, "unbounded affinity starves the minority to the end");
    assert!(
        pos_bounded <= 2,
        "run bound 2 must serve the minority within one bounded run, got {pos_bounded}"
    );
    assert!(
        q_bounded < q_unbounded * 0.5,
        "bounded queue delay {q_bounded} not well below unbounded {q_unbounded}"
    );
}

/// One fuzz run pinned to an event-loop mode (calendar heap vs the
/// scan-based reference), returning everything the bit-match gate
/// compares: completion records, the token stream, the full stats block,
/// and the scheduler's event/scan counters.
fn fuzz_run_cal(
    seed: u64,
    policy: PolicyKind,
    batch: usize,
    chunk: Option<usize>,
    chips: usize,
    calendar: bool,
) -> (Vec<RequestResult>, Vec<TokenEvent>, ServerStats, SchedCounters) {
    let mut exp = exp_1b(256);
    exp.shard.n_chips = chips;
    let mut s = ServerBuilder::from_experiment(exp)
        .max_batch(batch)
        .policy_kind(policy)
        .prefill_chunk(chunk)
        .calendar(calendar)
        .build()
        .expect("server");
    for a in 0..FUZZ_ADAPTERS {
        s.register_adapter(AdapterId(a));
    }
    for r in fuzz_trace(seed) {
        s.submit(r).unwrap();
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let results = s.drain(Some(&tx)).unwrap();
    drop(tx);
    let events: Vec<TokenEvent> = rx.iter().collect();
    let stats = s.stats();
    let counters = s.sched_counters();
    (results, events, stats, counters)
}

#[test]
fn calendar_bitmatches_scan_loop_on_fuzz_traces() {
    // The calendar event core must be invisible: same completion records,
    // same token-stream bits, same percentile bits, and — because both
    // modes execute the identical event sequence — the same event count.
    // Only the cost of *locating* the next event may differ.
    for seed in [1u64, 7, 42] {
        for &batch in &[1usize, 4] {
            for &chunk in &[None, Some(64)] {
                for &chips in &[1usize, 4] {
                    for policy in [
                        PolicyKind::Fcfs,
                        PolicyKind::AdapterAffinity,
                        PolicyKind::ShortestJobFirst,
                    ] {
                        let label = format!(
                            "seed {seed} / {} / batch {batch} / chunk {chunk:?} / chips {chips}",
                            policy.name()
                        );
                        let (rc, ec, sc, cc) =
                            fuzz_run_cal(seed, policy, batch, chunk, chips, true);
                        let (rs, es, ss, cs) =
                            fuzz_run_cal(seed, policy, batch, chunk, chips, false);

                        assert_eq!(rc.len(), rs.len(), "{label}: completions");
                        for (a, b) in rc.iter().zip(&rs) {
                            assert_eq!(a.request, b.request, "{label}: order");
                            assert_eq!(a.adapter.0, b.adapter.0, "{label}");
                            assert_eq!(a.swap, b.swap, "{label}: swap of {}", a.request);
                            assert_eq!(a.tokens_out, b.tokens_out, "{label}");
                            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits(), "{label}");
                            assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
                            assert_eq!(a.queue_s.to_bits(), b.queue_s.to_bits(), "{label}");
                            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
                            assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
                            assert_eq!(a.stall_s.to_bits(), b.stall_s.to_bits(), "{label}");
                            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
                        }

                        assert_eq!(ec.len(), es.len(), "{label}: token events");
                        for (a, b) in ec.iter().zip(&es) {
                            assert_eq!(a.request, b.request, "{label}: token order");
                            assert_eq!(a.index, b.index, "{label}: token index");
                            assert_eq!(a.at_s.to_bits(), b.at_s.to_bits(), "{label}: token time");
                        }

                        assert_eq!(sc.sim_time_s.to_bits(), ss.sim_time_s.to_bits(), "{label}");
                        assert_eq!(sc.total_tokens, ss.total_tokens, "{label}");
                        assert_eq!(sc.adapter_swaps, ss.adapter_swaps, "{label}");
                        assert_eq!(sc.adapter_hits, ss.adapter_hits, "{label}");
                        assert_eq!(sc.mean_ttft_s.to_bits(), ss.mean_ttft_s.to_bits(), "{label}");
                        assert_eq!(sc.mean_itl_ms.to_bits(), ss.mean_itl_ms.to_bits(), "{label}");
                        for (x, y, what) in [
                            (sc.ttft, ss.ttft, "ttft"),
                            (sc.itl, ss.itl, "itl"),
                            (sc.queue, ss.queue, "queue"),
                        ] {
                            assert_eq!(x.mean.to_bits(), y.mean.to_bits(), "{label}: {what}");
                            assert_eq!(x.p50.to_bits(), y.p50.to_bits(), "{label}: {what}");
                            assert_eq!(x.p95.to_bits(), y.p95.to_bits(), "{label}: {what}");
                            assert_eq!(x.p99.to_bits(), y.p99.to_bits(), "{label}: {what}");
                        }

                        assert_eq!(cc.events, cs.events, "{label}: event count");
                        assert!(cc.events > 0 && cc.scanned > 0, "{label}: live counters");
                        assert!(cs.scanned > 0, "{label}: scan-mode counter");
                    }
                }
            }
        }
    }
}

/// One fuzz run pinned to a decode mode (continuous paged-KV vs the
/// retained lockstep reservation), with an optional pool-capacity
/// override and fast-forward toggle.
fn fuzz_run_cont(
    seed: u64,
    policy: PolicyKind,
    batch: usize,
    chunk: Option<usize>,
    continuous: bool,
    pool_pages: Option<usize>,
    fast_forward: bool,
) -> (Vec<RequestResult>, Vec<TokenEvent>, ServerStats) {
    let mut s = ServerBuilder::from_experiment(exp_1b(256))
        .max_batch(batch)
        .policy_kind(policy)
        .prefill_chunk(chunk)
        .continuous(continuous)
        .kv_pool_pages(pool_pages)
        .decode_fast_forward(fast_forward)
        .build()
        .expect("server");
    for a in 0..FUZZ_ADAPTERS {
        s.register_adapter(AdapterId(a));
    }
    for r in fuzz_trace(seed) {
        s.submit(r).unwrap();
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let results = s.drain(Some(&tx)).unwrap();
    drop(tx);
    let events: Vec<TokenEvent> = rx.iter().collect();
    let stats = s.stats();
    (results, events, stats)
}

#[test]
fn continuous_bitmatches_lockstep_when_capacity_is_ample() {
    // The tentpole's acceptance gate: with pool capacity >= total demand
    // (the derived 1B pool holds 128 pages; the fuzz traces need < 40)
    // the admission gate never blocks and no preemption fires, so paged
    // bookkeeping has zero timing effect — continuous mode must match
    // retained lockstep mode on every completion field, token-stream
    // bit, and stats percentile.
    for seed in [1u64, 7, 42] {
        for &(batch, chunk) in &[(1usize, None), (4, None), (4, Some(128))] {
            for policy in [
                PolicyKind::Fcfs,
                PolicyKind::AdapterAffinity,
                PolicyKind::ShortestJobFirst,
            ] {
                let label = format!(
                    "seed {seed} / {} / batch {batch} / chunk {chunk:?}",
                    policy.name()
                );
                let (rc, ec, sc) =
                    fuzz_run_cont(seed, policy, batch, chunk, true, None, true);
                let (rl, el, sl) =
                    fuzz_run_cont(seed, policy, batch, chunk, false, None, true);

                assert_eq!(rc.len(), rl.len(), "{label}: completions");
                for (a, b) in rc.iter().zip(&rl) {
                    assert_eq!(a.request, b.request, "{label}: order");
                    assert_eq!(a.adapter.0, b.adapter.0, "{label}");
                    assert_eq!(a.swap, b.swap, "{label}: swap of {}", a.request);
                    assert_eq!(a.tokens_out, b.tokens_out, "{label}");
                    assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits(), "{label}");
                    assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
                    assert_eq!(a.queue_s.to_bits(), b.queue_s.to_bits(), "{label}");
                    assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
                    assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
                    assert_eq!(a.stall_s.to_bits(), b.stall_s.to_bits(), "{label}");
                    assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
                }
                assert_eq!(ec.len(), el.len(), "{label}: token events");
                for (a, b) in ec.iter().zip(&el) {
                    assert_eq!(a.request, b.request, "{label}: token order");
                    assert_eq!(a.index, b.index, "{label}: token index");
                    assert_eq!(a.at_s.to_bits(), b.at_s.to_bits(), "{label}: token time");
                }
                assert_eq!(sc.sim_time_s.to_bits(), sl.sim_time_s.to_bits(), "{label}");
                assert_eq!(sc.total_tokens, sl.total_tokens, "{label}");
                assert_eq!(sc.adapter_swaps, sl.adapter_swaps, "{label}");
                assert_eq!(sc.adapter_hits, sl.adapter_hits, "{label}");
                for (x, y, what) in [
                    (sc.ttft, sl.ttft, "ttft"),
                    (sc.itl, sl.itl, "itl"),
                    (sc.queue, sl.queue, "queue"),
                ] {
                    assert_eq!(x.mean.to_bits(), y.mean.to_bits(), "{label}: {what}");
                    assert_eq!(x.p50.to_bits(), y.p50.to_bits(), "{label}: {what}");
                    assert_eq!(x.p95.to_bits(), y.p95.to_bits(), "{label}: {what}");
                    assert_eq!(x.p99.to_bits(), y.p99.to_bits(), "{label}: {what}");
                }
                // Continuous mode actually paged (and returned) the KV.
                assert!(sc.kv_page_allocs > 0, "{label}: pages moved");
                assert_eq!(sc.kv_page_allocs, sc.kv_page_frees, "{label}: drained");
                assert_eq!(sc.kv_used_pages, 0, "{label}: pool empty at end");
                assert_eq!(sc.preemptions, 0, "{label}: ample capacity");
                assert_eq!(sl.kv_page_allocs, 0, "{label}: lockstep never pages");
            }
        }
    }
}

#[test]
fn continuous_preemption_replays_bitwise_across_ff_modes() {
    // Engineered over-capacity backlog: a 5-page pool with four slots
    // that each grow to 3 pages forces eviction. The victim order is
    // deterministic (youngest admission first, restart-from-prefill),
    // so two replays are bit-identical — and the fast-forward path must
    // agree with the stepwise path exactly.
    let run = |ff: bool| {
        let mut s = ServerBuilder::from_experiment(exp_1b(128))
            .max_batch(4)
            .continuous(true)
            .kv_pool_pages(Some(5))
            .decode_fast_forward(ff)
            .build()
            .unwrap();
        s.register_adapter(AdapterId(0));
        for i in 0..8u64 {
            s.submit(Request::new(i, AdapterId(0), 128, 140).at(i as f64 * 0.001)).unwrap();
        }
        let results = s.drain(None).unwrap();
        (results, s.stats())
    };
    let (r1, s1) = run(true);
    let (r2, s2) = run(true);
    let (r3, s3) = run(false);
    assert_eq!(r1.len(), 8, "conservation under preemption");
    assert!(s1.preemptions > 0, "the backlog must preempt");
    assert!(s1.preempted_tokens > 0);
    assert_eq!(s1.kv_page_allocs, s1.kv_page_frees, "page conservation");
    assert_eq!(s1.kv_used_pages, 0);
    assert_eq!(s1.kv_peak_pages, 5, "pressure fills the pool");
    for (other_r, other_s, label) in [(&r2, &s2, "replay"), (&r3, &s3, "ff-off")] {
        assert_eq!(r1.len(), other_r.len(), "{label}");
        for (a, b) in r1.iter().zip(other_r.iter()) {
            assert_eq!(a.request, b.request, "{label}: completion order");
            assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
            assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
        }
        assert_eq!(s1.preemptions, other_s.preemptions, "{label}");
        assert_eq!(s1.preempted_tokens, other_s.preempted_tokens, "{label}");
        assert_eq!(s1.kv_page_allocs, other_s.kv_page_allocs, "{label}");
        assert_eq!(s1.kv_page_frees, other_s.kv_page_frees, "{label}");
        assert_eq!(s1.kv_peak_pages, other_s.kv_peak_pages, "{label}");
        assert_eq!(s1.sim_time_s.to_bits(), other_s.sim_time_s.to_bits(), "{label}");
    }
}

#[test]
fn continuous_generated_traces_hold_conservation() {
    // Workload-generator traces through continuous mode: every submitted
    // request completes exactly once, pages conserve, and the calendar
    // vs scan loops agree on the clock.
    use primal::trace::{WorkloadKind, WorkloadSpec};
    for kind in [WorkloadKind::Poisson, WorkloadKind::Bursty, WorkloadKind::Diurnal] {
        let run = |calendar: bool| {
            let mut spec = WorkloadSpec::new(kind, 11, 48);
            spec.adapters = FUZZ_ADAPTERS as usize;
            spec.max_input = 256;
            spec.rate_per_s = 400.0;
            let mut s = ServerBuilder::from_experiment(exp_1b(256))
                .max_batch(4)
                .policy_kind(PolicyKind::AdapterAffinity)
                .continuous(true)
                .calendar(calendar)
                .build()
                .unwrap();
            for a in 0..FUZZ_ADAPTERS {
                s.register_adapter(AdapterId(a));
            }
            for r in spec.generate() {
                s.submit(r).unwrap();
            }
            let results = s.drain(None).unwrap();
            (results, s.stats())
        };
        let (rc, sc) = run(true);
        let (rs, ss) = run(false);
        let label = kind.name();
        assert_eq!(rc.len(), 48, "{label}: conservation");
        let mut ids: Vec<u64> = rc.iter().map(|r| r.request).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..48u64).collect::<Vec<_>>(), "{label}: ids");
        assert_eq!(sc.kv_page_allocs, sc.kv_page_frees, "{label}: page conservation");
        assert_eq!(sc.kv_used_pages, 0, "{label}");
        assert_eq!(rc.len(), rs.len(), "{label}: calendar vs scan");
        for (a, b) in rc.iter().zip(&rs) {
            assert_eq!(a.request, b.request, "{label}");
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
        }
        assert_eq!(sc.sim_time_s.to_bits(), ss.sim_time_s.to_bits(), "{label}");
        assert_eq!(sc.kv_page_allocs, ss.kv_page_allocs, "{label}");
    }
}

// ---- cross-request prefix reuse fuzz axis --------------------------------

/// One shared-prefix workload run through a continuous-mode server with
/// the trace's preamble library registered (or not, for the plain
/// baseline), returning completions + the full stats block.
fn prefix_fuzz_run(
    seed: u64,
    policy: PolicyKind,
    batch: usize,
    chunk: Option<usize>,
    share: f64,
    register: bool,
    fast_forward: bool,
) -> (Vec<RequestResult>, ServerStats, u64) {
    use primal::trace::{WorkloadKind, WorkloadSpec};
    let mut spec = WorkloadSpec::new(WorkloadKind::Prefix, seed, 24);
    spec.adapters = FUZZ_ADAPTERS as usize;
    spec.max_input = 256;
    spec.prefix_share = share;
    spec.rate_per_s = 200.0;
    let mut s = ServerBuilder::from_experiment(exp_1b(256))
        .max_batch(batch)
        .policy_kind(policy)
        .prefill_chunk(chunk)
        .continuous(true)
        .decode_fast_forward(fast_forward)
        .build()
        .expect("server");
    for a in 0..FUZZ_ADAPTERS {
        s.register_adapter(AdapterId(a));
    }
    if register {
        for (p, chain) in spec.preamble_library().chains().iter().enumerate() {
            s.register_preamble(primal::coordinator::PreambleId(p as u32), chain.clone())
                .expect("register preamble");
        }
    }
    for r in spec.generate() {
        s.submit(r).unwrap();
    }
    let results = s.drain(None).unwrap();
    let monolithic =
        s.stats().prefix_admissions * s.prefill_template_cycles() * s.n_layers() as u64;
    let stats = s.stats();
    (results, stats, monolithic)
}

#[test]
fn prefix_fuzz_holds_conservation_across_modes() {
    // The tentpole's conservation gates over policies x batch x chunk x
    // share x seed: (a) prefill FLOP conservation — cycles saved by hits
    // plus cycles charged for misses equal the monolithic prefill cost of
    // every preambled admission, as exact u64s; (b) refcount conservation
    // — every intern is released, every created node is freed, nothing
    // lives past drain; (c) page conservation; (d) bitwise replay.
    for seed in [7u64, 42] {
        for &(batch, chunk) in &[(2usize, None), (4, None), (4, Some(128))] {
            for policy in [
                PolicyKind::Fcfs,
                PolicyKind::AdapterAffinity,
                PolicyKind::PrefixAffinity,
            ] {
                for &share in &[0.5f64, 1.0] {
                    let label = format!(
                        "seed {seed} / {} / batch {batch} / chunk {chunk:?} / share {share}",
                        policy.name()
                    );
                    let (results, st, monolithic) =
                        prefix_fuzz_run(seed, policy, batch, chunk, share, true, true);
                    assert_eq!(results.len(), 24, "{label}: conservation");
                    let mut ids: Vec<u64> = results.iter().map(|r| r.request).collect();
                    ids.sort_unstable();
                    assert_eq!(ids, (0..24u64).collect::<Vec<_>>(), "{label}: ids");

                    assert!(st.prefix_admissions > 0, "{label}: shared requests admitted");
                    assert_eq!(
                        st.prefix_prefill_cycles_saved + st.prefix_prefill_cycles_charged,
                        monolithic,
                        "{label}: prefill FLOP conservation"
                    );
                    assert_eq!(st.prefix_interns, st.prefix_releases, "{label}: refcounts");
                    assert_eq!(
                        st.prefix_nodes_created, st.prefix_nodes_freed,
                        "{label}: node lifecycle"
                    );
                    assert_eq!(st.prefix_live_nodes, 0, "{label}: cache drained");
                    assert!(
                        st.prefix_hit_blocks + st.prefix_miss_blocks >= st.prefix_interns,
                        "{label}: every interned chain is at least one block"
                    );
                    assert_eq!(st.kv_page_allocs, st.kv_page_frees, "{label}: pages");
                    assert_eq!(st.kv_used_pages, 0, "{label}: pool empty");

                    // Bitwise replay determinism.
                    let (r2, s2, _) =
                        prefix_fuzz_run(seed, policy, batch, chunk, share, true, true);
                    assert_eq!(st.sim_time_s.to_bits(), s2.sim_time_s.to_bits(), "{label}");
                    assert_eq!(st.prefix_hit_blocks, s2.prefix_hit_blocks, "{label}");
                    assert_eq!(
                        st.prefix_prefill_cycles_saved, s2.prefix_prefill_cycles_saved,
                        "{label}"
                    );
                    for (a, b) in results.iter().zip(&r2) {
                        assert_eq!(a.request, b.request, "{label}: replay order");
                        assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
                        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
                    }

                    // Fast-forward must be invisible on the prefix axis too.
                    let (r3, s3, _) =
                        prefix_fuzz_run(seed, policy, batch, chunk, share, true, false);
                    assert_eq!(st.sim_time_s.to_bits(), s3.sim_time_s.to_bits(), "{label}: ff");
                    assert_eq!(st.prefix_hit_blocks, s3.prefix_hit_blocks, "{label}: ff");
                    assert_eq!(st.preempted_tokens, s3.preempted_tokens, "{label}: ff");
                    for (a, b) in results.iter().zip(&r3) {
                        assert_eq!(a.request, b.request, "{label}: ff order");
                        assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}: ff");
                        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}: ff");
                    }
                }
            }
        }
    }
}

#[test]
fn prefix_share_zero_bitmatches_plain_continuous() {
    // With sharing disabled the prefix machinery must be invisible: a
    // share-0 trace (no request carries a preamble) through a server with
    // the library registered bit-matches the same trace through a plain
    // continuous server with no registrations at all — and every prefix
    // counter stays zero.
    for &(batch, chunk) in &[(2usize, None), (4usize, Some(128))] {
        let label = format!("batch {batch} / chunk {chunk:?}");
        let (rp, sp, _) =
            prefix_fuzz_run(7, PolicyKind::Fcfs, batch, chunk, 0.0, true, true);
        let (rn, sn, _) =
            prefix_fuzz_run(7, PolicyKind::Fcfs, batch, chunk, 0.0, false, true);
        assert_eq!(rp.len(), rn.len(), "{label}");
        for (a, b) in rp.iter().zip(&rn) {
            assert_eq!(a.request, b.request, "{label}: order");
            assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
            assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
        }
        assert_eq!(sp.sim_time_s.to_bits(), sn.sim_time_s.to_bits(), "{label}");
        assert_eq!(sp.kv_page_allocs, sn.kv_page_allocs, "{label}: page churn");
        assert_eq!(sp.kv_peak_pages, sn.kv_peak_pages, "{label}");
        for v in [
            sp.prefix_admissions,
            sp.prefix_interns,
            sp.prefix_releases,
            sp.prefix_hit_blocks,
            sp.prefix_miss_blocks,
            sp.prefix_prefill_cycles_saved,
            sp.prefix_rram_passes_saved,
        ] {
            assert_eq!(v, 0, "{label}: prefix counters silent at share 0");
        }
    }
}

#[test]
fn chunked_continuous_preemption_charges_prefill_and_bitmatches_ff() {
    // Continuous x chunked prefill under an engineered eviction: with
    // 16-token pages and a 33-page pool, the resident (256-token) slot
    // holds 17 pages and needs its 18th exactly at generated == 16. A
    // newcomer arriving inside that 16th decode step admits into the
    // last 16 free pages, finishes exactly one 128-token prefill chunk,
    // and is then the LIFO victim of the resident's growth — a mid-chunk
    // PrefillJob, which must (a) release its pages and (b) charge the
    // prompt tokens already prefilled to `preempted_tokens`. The
    // historic undercount left that ledger at zero when only jobs were
    // evicted. The fast-forward and stepwise paths must agree bit for
    // bit, replays included.
    let build = |ff: bool| {
        let mut s = ServerBuilder::from_experiment(exp_1b(256))
            .max_batch(2)
            .prefill_chunk(Some(64))
            .continuous(true)
            .kv_page_tokens(16)
            .kv_pool_pages(Some(33))
            .decode_fast_forward(ff)
            .build()
            .unwrap();
        s.register_adapter(AdapterId(0));
        s
    };
    // Probe the ends of the resident's 15th and 16th decode steps; the
    // midpoint lands the newcomer strictly inside the eviction window.
    let mark = |out: usize| {
        let mut s = build(false);
        s.submit(Request::new(0, AdapterId(0), 256, out)).unwrap();
        s.drain(None).unwrap();
        s.stats().sim_time_s
    };
    let t1 = 0.5 * (mark(15) + mark(16));
    let run = |ff: bool| {
        let mut s = build(ff);
        s.submit(Request::new(0, AdapterId(0), 256, 200)).unwrap();
        s.submit(Request::new(1, AdapterId(0), 256, 32).at(t1)).unwrap();
        let results = s.drain(None).unwrap();
        (results, s.stats())
    };
    let (r1, s1) = run(true);
    let (r2, s2) = run(true);
    let (r3, s3) = run(false);
    assert_eq!(r1.len(), 2, "conservation under preemption");
    assert_eq!(s1.preemptions, 1, "the engineered famine evicts exactly the newcomer");
    assert_eq!(
        s1.preempted_tokens, 128,
        "the mid-prefill victim's one finished chunk must be charged"
    );
    assert_eq!(s1.kv_page_allocs, s1.kv_page_frees, "page conservation");
    assert_eq!(s1.kv_used_pages, 0);
    for (other_r, other_s, label) in [(&r2, &s2, "replay"), (&r3, &s3, "ff-off")] {
        assert_eq!(r1.len(), other_r.len(), "{label}");
        for (a, b) in r1.iter().zip(other_r.iter()) {
            assert_eq!(a.request, b.request, "{label}: completion order");
            assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "{label}");
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
            assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
        }
        assert_eq!(s1.preemptions, other_s.preemptions, "{label}");
        assert_eq!(s1.preempted_tokens, other_s.preempted_tokens, "{label}");
        assert_eq!(s1.kv_page_allocs, other_s.kv_page_allocs, "{label}");
        assert_eq!(s1.sim_time_s.to_bits(), other_s.sim_time_s.to_bits(), "{label}");
    }
}

#[test]
fn token_stream_covers_batched_requests() {
    let mut s = server_1b(256, 3, PolicyKind::Fcfs, 1);
    for i in 0..3u64 {
        s.submit(Request::new(i, AdapterId(0), 256, 12)).unwrap();
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let results = s.drain(Some(&tx)).unwrap();
    drop(tx);
    let events: Vec<_> = rx.iter().collect();
    assert_eq!(events.len(), 3 * 12);
    for req in 0..3u64 {
        let times: Vec<f64> = events
            .iter()
            .filter(|e| e.request == req)
            .map(|e| e.at_s)
            .collect();
        assert_eq!(times.len(), 12);
        assert!(times.windows(2).all(|w| w[1] > w[0]), "monotone stream");
    }
    // Batched requests interleave: request 1 finishes before request 0
    // would have under serial scheduling, and stalls are accounted.
    assert!(results.iter().all(|r| r.stall_s >= 0.0));
}
