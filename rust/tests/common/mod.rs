//! Shared integration-test helpers.
//!
//! Cargo compiles `tests/common/` into every suite that declares
//! `mod common;` (a directory is not its own test target), so the
//! config/server constructors and the nearest-rank percentile live here
//! once instead of being copy-pasted per suite. Each suite uses a
//! subset, hence the module-wide `dead_code` allowance.
#![allow(dead_code)]

use primal::config::{ExperimentConfig, LoraTarget, ModelId, PolicyKind};
use primal::coordinator::{AdapterId, Server, ServerBuilder};

/// The paper point for `model` at context `ctx` with the Q+V LoRA targets
/// (the configuration every Table II cell uses).
pub fn cfg_of(model: ModelId, ctx: usize) -> ExperimentConfig {
    ExperimentConfig::paper_point(model, &[LoraTarget::Q, LoraTarget::V], ctx)
}

/// The 1B paper point — the cheap model the serving suites iterate on.
pub fn exp_1b(ctx: usize) -> ExperimentConfig {
    cfg_of(ModelId::Llama32_1b, ctx)
}

/// A 1B legacy-mode server with `adapters` registered adapters.
pub fn server_1b(ctx: usize, max_batch: usize, policy: PolicyKind, adapters: u32) -> Server {
    let mut s = ServerBuilder::from_experiment(exp_1b(ctx))
        .max_batch(max_batch)
        .policy_kind(policy)
        .build()
        .expect("server");
    for a in 0..adapters {
        s.register_adapter(AdapterId(a));
    }
    s
}

/// Nearest-rank p95 (the same `ceil(q*n)` rank `latency_stats` uses).
pub fn p95(samples: &mut Vec<f64>) -> f64 {
    samples.sort_by(f64::total_cmp);
    let rank = ((0.95 * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
    samples[rank - 1]
}
