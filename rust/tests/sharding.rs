//! Integration: the multi-chip sharded mapping — cross-shard invariants.
//!
//! The sharded tier must be an *extension*, not a fork, of the
//! single-chip model. Four property families gate that, the same
//! bit-match discipline PR 3 established for batching:
//!
//!  1. `Simulator::run_sharded(1)` bit-matches `Simulator::run` on every
//!     Table II grid point (all sharded terms collapse exactly);
//!  2. per-layer FLOP/byte totals are conserved across every shard count
//!     (exact integer shares, at both the `ShardPlan` and the sliced
//!     program level);
//!  3. the per-chip KV footprint is monotone non-increasing in the chip
//!     count — the lever that opens the 13B batch >= 2 points one chip's
//!     scratchpads reject;
//!  4. the chip-ring all-reduce cost is strictly increasing in the shard
//!     count for a fixed layer size.

mod common;

use common::cfg_of;
use primal::config::{ExperimentConfig, LoraTarget, ModelId, ShardConfig};
use primal::dataflow::{decode_program, prefill_program, shard_program_slice};
use primal::mapping::{map_model, split_even, ShardPlan};
use primal::metrics::{paper_grid, run_point, run_point_sharded};
use primal::noc::ChipMesh;
use primal::sim::{program_cost, PhaseCost, Simulator};

// ---- 1. one-chip bit-match ------------------------------------------------

#[test]
fn one_chip_bitmatches_single_chip_on_all_12_grid_points() {
    for cfg in &paper_grid() {
        let serial = run_point(cfg);
        let sharded = run_point_sharded(cfg, 1, 1);
        let label = format!(
            "{} {} {}",
            serial.model, serial.lora_label, serial.input_tokens
        );
        assert_eq!(sharded.n_chips, 1, "{label}");
        assert_eq!(serial.ttft_s.to_bits(), sharded.ttft_s.to_bits(), "{label}: ttft");
        assert_eq!(serial.itl_ms.to_bits(), sharded.itl_ms.to_bits(), "{label}: itl");
        assert_eq!(
            serial.throughput_tps.to_bits(),
            sharded.throughput_tps.to_bits(),
            "{label}: throughput"
        );
        assert_eq!(
            serial.avg_power_w.to_bits(),
            sharded.avg_power_w.to_bits(),
            "{label}: power"
        );
        assert_eq!(
            serial.efficiency_tpj.to_bits(),
            sharded.efficiency_tpj.to_bits(),
            "{label}: efficiency"
        );
        assert_eq!(serial.total_cycles, sharded.total_cycles, "{label}: cycles");
        assert_eq!(
            serial.total_energy_j.to_bits(),
            sharded.total_energy_j.to_bits(),
            "{label}: energy"
        );
        assert_eq!(serial.total_cts, sharded.total_cts, "{label}: CTs");
    }
}

/// Anchors the 1-chip path to *pre-refactor* numbers, not to itself:
/// `run()` now delegates to `run_sharded_batched`, so serial-vs-1-chip
/// comparisons alone would pass even if the collapse regressed on both
/// sides. These total-cycle counts were blessed from the operation-exact
/// Python mirror (`python/tools/sim_mirror.py`, the same source as
/// `benches/baselines/sim_proxy.txt`) and pin the single-chip engine
/// absolutely; any sharded term leaking into the 1-chip path moves them.
#[test]
fn one_chip_grid_matches_mirror_blessed_cycle_counts() {
    const GOLDEN: &[(ModelId, &[LoraTarget], usize, u64)] = &[
        (ModelId::Llama32_1b, &[LoraTarget::Q], 1024, 1_665_971_520),
        (ModelId::Llama32_1b, &[LoraTarget::Q], 2048, 5_681_908_288),
        (ModelId::Llama32_1b, &[LoraTarget::Q, LoraTarget::V], 1024, 1_665_986_240),
        (ModelId::Llama32_1b, &[LoraTarget::Q, LoraTarget::V], 2048, 5_681_923_008),
        (ModelId::Llama3_8b, &[LoraTarget::Q], 1024, 6_649_328_128),
        (ModelId::Llama3_8b, &[LoraTarget::Q], 2048, 17_620_567_552),
        (ModelId::Llama3_8b, &[LoraTarget::Q, LoraTarget::V], 1024, 6_649_357_568),
        (ModelId::Llama3_8b, &[LoraTarget::Q, LoraTarget::V], 2048, 17_620_596_992),
        (ModelId::Llama2_13b, &[LoraTarget::Q], 1024, 12_121_800_208),
        (ModelId::Llama2_13b, &[LoraTarget::Q], 2048, 30_783_471_488),
        (ModelId::Llama2_13b, &[LoraTarget::Q, LoraTarget::V], 1024, 12_121_859_088),
        (ModelId::Llama2_13b, &[LoraTarget::Q, LoraTarget::V], 2048, 30_783_530_368),
    ];
    for &(model, targets, ctx, cycles) in GOLDEN {
        let cfg = ExperimentConfig::paper_point(model, targets, ctx);
        let r = Simulator::new(&cfg).run_sharded(1);
        assert_eq!(
            r.total_cycles, cycles,
            "{model:?} {targets:?} {ctx}: 1-chip cycles drifted from the \
             mirror-blessed single-chip value"
        );
    }
}

// ---- 2. conservation across shard counts ----------------------------------

#[test]
fn shard_plan_conserves_layer_totals_for_all_models_and_counts() {
    for model in ModelId::all_paper() {
        let cfg = cfg_of(model, 2048);
        let mapping = map_model(&cfg);
        let m = &cfg.model;
        let lora_params = cfg.lora.layer_params(m.hidden, m.q_dim(), m.kv_dim()) as u64;
        for n in [1usize, 2, 3, 4, 6, 8] {
            let p = ShardPlan::new(&cfg, &mapping, n);
            assert_eq!(p.n_chips, n);
            let smac: u64 = p.slices.iter().map(|s| s.smac_weights).sum();
            let heads: u64 = p.slices.iter().map(|s| s.attn_heads).sum();
            let kv: u64 = p.slices.iter().map(|s| s.kv_token_bytes).sum();
            let lora: u64 = p.slices.iter().map(|s| s.lora_params).sum();
            assert_eq!(smac, m.layer_weights() as u64, "{model:?}/{n}: weight FLOPs");
            assert_eq!(heads, m.n_heads as u64, "{model:?}/{n}: heads");
            assert_eq!(kv, mapping.layers[0].kv_token_bytes as u64, "{model:?}/{n}: KV");
            assert_eq!(lora, lora_params, "{model:?}/{n}: LoRA params");
        }
    }
}

#[test]
fn sliced_programs_conserve_flops_and_resident_bytes() {
    // Both program kinds, both a GQA and an MHA model, chips in {2, 4}.
    for model in [ModelId::Llama3_8b, ModelId::Llama2_13b] {
        let cfg = cfg_of(model, 1024);
        let mapping = map_model(&cfg);
        let lm0 = &mapping.layers[0];
        let programs = [
            decode_program(&cfg, lm0, 1536),
            prefill_program(&cfg, lm0, 128, 512),
        ];
        for prog in &programs {
            let full = program_cost(prog, &cfg.system, &cfg.calib);
            for n in [2usize, 4] {
                let mut sum = PhaseCost::default();
                for chip in 0..n {
                    let sliced = shard_program_slice(prog, chip, n);
                    let c = program_cost(&sliced, &cfg.system, &cfg.calib);
                    sum.rram_passes += c.rram_passes;
                    sum.sram_passes += c.sram_passes;
                    sum.dmac_macs += c.dmac_macs;
                    sum.softmax_elems += c.softmax_elems;
                    sum.spad_bytes += c.spad_bytes;
                    sum.d2d_bytes += c.d2d_bytes;
                }
                // FLOP classes (crossbar passes, LoRA passes, attention
                // MACs, softmax) and the sharded KV's scratchpad bytes
                // partition exactly.
                assert_eq!(sum.rram_passes, full.rram_passes, "{model:?}/{n}");
                assert_eq!(sum.sram_passes, full.sram_passes, "{model:?}/{n}");
                assert_eq!(sum.dmac_macs, full.dmac_macs, "{model:?}/{n}");
                assert_eq!(sum.softmax_elems, full.softmax_elems, "{model:?}/{n}");
                assert_eq!(sum.spad_bytes, full.spad_bytes, "{model:?}/{n}");
                // Activation deliveries replicate whole per chip.
                assert_eq!(sum.d2d_bytes, full.d2d_bytes * n as u64, "{model:?}/{n}");
            }
        }
    }
}

#[test]
fn split_even_partitions_exactly() {
    for total in [0u64, 1, 7, 40, 65_521, u32::MAX as u64] {
        for n in 1usize..=9 {
            let shares = split_even(total, n);
            assert_eq!(shares.iter().sum::<u64>(), total, "{total}/{n}");
            let (max, min) = (shares.iter().max().unwrap(), shares.iter().min().unwrap());
            assert!(max - min <= 1, "{total}/{n}: uneven by more than 1");
        }
    }
}

// ---- 3. per-chip KV footprint monotone ------------------------------------

#[test]
fn per_chip_kv_footprint_monotone_non_increasing() {
    for model in ModelId::all_paper() {
        let cfg = cfg_of(model, 2048);
        let mapping = map_model(&cfg);
        let tokens = cfg.input_tokens + cfg.output_tokens;
        for slots in [1usize, 4] {
            let mut prev = usize::MAX;
            for n in [1usize, 2, 4, 8] {
                let f = ShardPlan::new(&cfg, &mapping, n).kv_bytes_per_router(tokens, slots);
                assert!(
                    f <= prev,
                    "{model:?} slots {slots}: footprint {f} at {n} chips above {prev}"
                );
                prev = f;
            }
        }
    }
}

#[test]
fn sharding_opens_previously_kv_infeasible_13b_batch_points() {
    // PR 3 had to reject every 13B batch-4 point as KV-infeasible on one
    // chip; four chips divide each token's resident K+V share enough to
    // fit, and the sharded run completes with a well-formed report.
    let mut cfg = cfg_of(ModelId::Llama2_13b, 2048);
    cfg.serving.max_batch = 4;
    assert!(
        !cfg.validate().is_empty(),
        "13B 2048/2048 batch 4 must stay infeasible on one chip"
    );
    cfg.shard.n_chips = 2;
    assert!(!cfg.validate().is_empty(), "two chips are still short");
    cfg.shard.n_chips = 4;
    assert!(
        cfg.validate().is_empty(),
        "13B 2048/2048 batch 4 must be feasible on four chips: {:?}",
        cfg.validate()
    );
    let r = Simulator::new(&cfg).run_sharded_batched(4, 4);
    assert_eq!((r.batch, r.n_chips), (4, 4));
    assert!(r.ttft_s.is_finite() && r.ttft_s > 0.0);
    assert!(r.itl_ms.is_finite() && r.itl_ms > 0.0);
    assert!(r.throughput_tps.is_finite() && r.throughput_tps > 0.0);
    assert!(r.total_energy_j > 0.0);
    // And it beats the serial single-chip point: 4 requests' tokens over
    // the shared sharded pipeline.
    let serial = Simulator::new(&cfg_of(ModelId::Llama2_13b, 2048)).run();
    assert!(
        r.throughput_tps > serial.throughput_tps,
        "sharded b4 {} tok/s must beat serial {} tok/s",
        r.throughput_tps,
        serial.throughput_tps
    );
}

// ---- 4. all-reduce cost strictly increasing -------------------------------

#[test]
fn all_reduce_cost_strictly_increases_in_shard_count() {
    let shard = ShardConfig::default();
    // Fixed layer sizes: every paper model's hidden activation, decode
    // (1 token) and a full prefill block (128 tokens).
    for hidden in [2048usize, 4096, 5120] {
        for tokens in [1usize, 128] {
            let mut prev = 0u64;
            for n in [2usize, 3, 4, 6, 8] {
                let c = ChipMesh::new(&shard, n).layer_all_reduce_cycles(hidden, tokens);
                assert!(
                    c > prev,
                    "hidden {hidden} x{tokens}: {c} cycles at {n} chips not above {prev}"
                );
                prev = c;
            }
            assert_eq!(
                ChipMesh::new(&shard, 1).layer_all_reduce_cycles(hidden, tokens),
                0,
                "one chip must cost zero"
            );
        }
    }
}

// ---- sharded scaling shape -------------------------------------------------

#[test]
fn sharded_throughput_rises_and_efficiency_falls() {
    let cfg = cfg_of(ModelId::Llama32_1b, 1024);
    let sim = Simulator::new(&cfg);
    let c1 = sim.run_sharded(1);
    let c2 = sim.run_sharded(2);
    let c4 = sim.run_sharded(4);
    assert!(c2.throughput_tps > c1.throughput_tps);
    assert!(c4.throughput_tps > c2.throughput_tps);
    // Sub-linear: replicated activation streams + the all-reduce keep the
    // speedup well under ideal n-fold.
    assert!(c4.throughput_tps < c1.throughput_tps * 4.0);
    // The chip count multiplies idle CTs: power rises, tokens/J falls.
    assert!(c2.avg_power_w > c1.avg_power_w && c4.avg_power_w > c2.avg_power_w);
    assert!(c2.efficiency_tpj < c1.efficiency_tpj);
    assert!(c4.efficiency_tpj < c2.efficiency_tpj);
    assert_eq!(c4.total_cts, 4 * c1.total_cts);
}
