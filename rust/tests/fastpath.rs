//! Fast-path bit-identity suite: the closed-form decode summation
//! (engine), the scaled single-post energy accounting, the coordinator's
//! decode fast-forward, and the deterministic parallel sweep driver must
//! all be *invisible* — every observable number bit-identical to the
//! retained reference paths.
//!
//! Coverage: the full 12-point Table II grid x batch {1, 4} x chips
//! {1, 2, 4} (KV-infeasible combos skipped loudly, mirroring
//! `benches/table2.rs`), a randomized sweep over models x kv ranges x
//! batch x chips x srpg, coordinator fast-forward on heterogeneous-slot
//! batches, and sweep-driver determinism across worker counts.

use primal::config::{ExperimentConfig, LoraTarget, ModelId, PolicyKind};
use primal::coordinator::{AdapterId, Request, ServerBuilder};
use primal::metrics::paper_grid;
use primal::sim::{sweep, LayerCostModel, SimReport, Simulator};
use primal::util::Rng;

fn assert_bit_identical(fast: &SimReport, slow: &SimReport, label: &str) {
    assert_eq!(fast.total_cycles, slow.total_cycles, "{label}: total_cycles");
    assert_eq!(
        fast.reprog_stall_cycles, slow.reprog_stall_cycles,
        "{label}: reprog stalls"
    );
    assert_eq!(fast.ttft_s.to_bits(), slow.ttft_s.to_bits(), "{label}: ttft_s");
    assert_eq!(fast.itl_ms.to_bits(), slow.itl_ms.to_bits(), "{label}: itl_ms");
    assert_eq!(
        fast.itl_first_ms.to_bits(),
        slow.itl_first_ms.to_bits(),
        "{label}: itl_first_ms"
    );
    assert_eq!(
        fast.itl_last_ms.to_bits(),
        slow.itl_last_ms.to_bits(),
        "{label}: itl_last_ms"
    );
    assert_eq!(
        fast.throughput_tps.to_bits(),
        slow.throughput_tps.to_bits(),
        "{label}: throughput"
    );
    assert_eq!(
        fast.avg_power_w.to_bits(),
        slow.avg_power_w.to_bits(),
        "{label}: avg_power"
    );
    assert_eq!(
        fast.efficiency_tpj.to_bits(),
        slow.efficiency_tpj.to_bits(),
        "{label}: efficiency"
    );
    assert_eq!(
        fast.total_energy_j.to_bits(),
        slow.total_energy_j.to_bits(),
        "{label}: total_energy_j"
    );
    // The full per-component energy breakdown, not just the total.
    let pairs = [
        (fast.energy.rram_j, slow.energy.rram_j, "rram_j"),
        (fast.energy.sram_j, slow.energy.sram_j, "sram_j"),
        (fast.energy.scratchpad_j, slow.energy.scratchpad_j, "scratchpad_j"),
        (fast.energy.router_j, slow.energy.router_j, "router_j"),
        (fast.energy.dmac_j, slow.energy.dmac_j, "dmac_j"),
        (fast.energy.network_j, slow.energy.network_j, "network_j"),
        (fast.energy.retention_j, slow.energy.retention_j, "retention_j"),
        (fast.energy.static_j, slow.energy.static_j, "static_j"),
    ];
    for (a, b, name) in pairs {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: energy.{name}");
    }
}

/// The acceptance grid: all 12 Table II points x batch {1, 4} x chips
/// {1, 2, 4}, closed form vs per-token reference, KV-infeasible combos
/// skipped loudly exactly like `benches/table2.rs` does.
#[test]
fn closed_form_bitmatches_reference_on_the_full_grid() {
    let mut ran = 0usize;
    let mut skipped = 0usize;
    for cfg in &paper_grid() {
        for batch in [1usize, 4] {
            for chips in [1usize, 2, 4] {
                let mut point = cfg.clone();
                point.serving.max_batch = batch;
                point.shard.n_chips = chips;
                let label = format!(
                    "{:?} ctx {} b{batch} c{chips}",
                    point.model.id, point.input_tokens
                );
                let problems = point.validate();
                if !problems.is_empty() {
                    for p in &problems {
                        eprintln!("skipping {label}: {p}");
                    }
                    skipped += 1;
                    continue;
                }
                let sim = Simulator::new(&point);
                let fast = sim.run_sharded_batched(batch, chips);
                let slow = sim.run_sharded_batched_reference(batch, chips);
                assert_bit_identical(&fast, &slow, &label);
                ran += 1;
            }
        }
    }
    // 12 points x 6 combos = 72, minus the KV-infeasible 13B batch-4
    // cells at low chip counts; assert the sweep actually exercised the
    // grid rather than skipping everything.
    assert!(ran >= 60, "only {ran} grid combos ran ({skipped} skipped)");
}

/// Randomized sweep: models x kv ranges (odd prompt/output lengths that
/// straddle sample-grid boundaries) x batch x chips x srpg.
#[test]
fn closed_form_bitmatches_reference_randomized() {
    let mut rng = Rng::new(0xFA57_7A7);
    let models = [ModelId::Llama32_1b, ModelId::Llama3_8b, ModelId::Llama2_13b];
    let mut ran = 0usize;
    for case in 0..40 {
        let model = models[rng.range(0, models.len())];
        let targets: &[LoraTarget] = if rng.range(0, 2) == 0 {
            &[LoraTarget::Q]
        } else {
            &[LoraTarget::Q, LoraTarget::V]
        };
        // Deliberately un-round lengths: boundary-straddling kv windows.
        let ctx = 16 + rng.range(0, 2500);
        let out = 1 + rng.range(0, 700);
        let batch = [1usize, 4][rng.range(0, 2)];
        let chips = [1usize, 2, 4][rng.range(0, 3)];
        let srpg = rng.range(0, 2) == 0;
        let mut cfg = ExperimentConfig::paper_point(model, targets, ctx);
        cfg.output_tokens = out;
        cfg.serving.max_batch = batch;
        cfg.shard.n_chips = chips;
        cfg.srpg = srpg;
        if !cfg.validate().is_empty() {
            continue; // KV-infeasible draw; the grid test reports those
        }
        let label = format!(
            "case {case}: {model:?} {ctx}/{out} b{batch} c{chips} srpg={srpg}"
        );
        let sim = Simulator::new(&cfg);
        let fast = sim.run_sharded_batched(batch, chips);
        let slow = sim.run_sharded_batched_reference(batch, chips);
        assert_bit_identical(&fast, &slow, &label);
        ran += 1;
    }
    assert!(ran >= 20, "too few feasible random cases ({ran})");
}

/// The coordinator fast-forward on *heterogeneous* slots: staggered
/// admissions put every slot at a different kv, so the window summation
/// exercises the per-slot segment sums and the max-kv pipeline term.
#[test]
fn coordinator_fast_forward_bitmatches_stepwise_heterogeneous() {
    let run = |ff: bool| {
        let mut s = ServerBuilder::from_experiment(ExperimentConfig::paper_point(
            ModelId::Llama32_1b,
            &[LoraTarget::Q, LoraTarget::V],
            256,
        ))
        .max_batch(4)
        .policy_kind(PolicyKind::Fcfs)
        .decode_fast_forward(ff)
        .build()
        .unwrap();
        s.register_adapter(AdapterId(0));
        // Same adapter, staggered arrivals and lengths: slots join the
        // batch at different times, so their kv positions diverge.
        for (i, (inp, out, at)) in [
            (256usize, 200usize, 0.0f64),
            (128, 150, 0.001),
            (300, 120, 0.002),
            (64, 260, 0.003),
        ]
        .iter()
        .enumerate()
        {
            s.submit(Request::new(i as u64, AdapterId(0), *inp, *out).at(*at)).unwrap();
        }
        let results = s.drain(None).unwrap();
        let stats = s.stats();
        (results, stats)
    };
    let (rf, sf) = run(true);
    let (rs, ss) = run(false);
    assert_eq!(rf.len(), rs.len());
    for (a, b) in rf.iter().zip(&rs) {
        assert_eq!(a.request, b.request);
        assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "req {}", a.request);
        assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "req {}", a.request);
        assert_eq!(a.stall_s.to_bits(), b.stall_s.to_bits(), "req {}", a.request);
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "req {}", a.request);
    }
    assert_eq!(sf.sim_time_s.to_bits(), ss.sim_time_s.to_bits());
    assert_eq!(sf.itl.p95.to_bits(), ss.itl.p95.to_bits());
    assert_eq!(sf.itl.mean.to_bits(), ss.itl.mean.to_bits());
}

/// Randomized coordinator property sweep: policies x batch x chips x
/// srpg, fast-forward on vs off, full completion-record equality.
#[test]
fn coordinator_fast_forward_bitmatches_stepwise_randomized() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..12 {
        let batch = 1 + rng.range(0, 4);
        let chips = [1usize, 2][rng.range(0, 2)];
        let srpg = rng.range(0, 2) == 0;
        let policy = [PolicyKind::Fcfs, PolicyKind::AdapterAffinity, PolicyKind::ShortestJobFirst]
            [rng.range(0, 3)];
        let n_req = 6 + rng.range(0, 6);
        let trace: Vec<(u64, u32, usize, usize, f64)> = (0..n_req)
            .map(|i| {
                (
                    i as u64,
                    rng.range(0, 2) as u32,
                    32 + rng.range(0, 400),
                    2 + rng.range(0, 60),
                    i as f64 * 0.0004 * rng.range(0, 5) as f64,
                )
            })
            .collect();
        let run = |ff: bool| {
            let mut exp = ExperimentConfig::paper_point(
                ModelId::Llama32_1b,
                &[LoraTarget::Q, LoraTarget::V],
                256,
            );
            exp.shard.n_chips = chips;
            exp.srpg = srpg;
            let mut s = ServerBuilder::from_experiment(exp)
                .max_batch(batch)
                .policy_kind(policy)
                .decode_fast_forward(ff)
                .build()
                .unwrap();
            s.register_adapter(AdapterId(0));
            s.register_adapter(AdapterId(1));
            for &(id, a, inp, out, at) in &trace {
                s.submit(Request::new(id, AdapterId(a), inp, out).at(at)).unwrap();
            }
            let results = s.drain(None).unwrap();
            (results, s.stats())
        };
        let (rf, sf) = run(true);
        let (rs, ss) = run(false);
        let label = format!("case {case} ({} b{batch} c{chips})", policy.name());
        assert_eq!(rf.len(), rs.len(), "{label}");
        for (a, b) in rf.iter().zip(&rs) {
            assert_eq!(a.request, b.request, "{label}");
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "{label}");
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}");
            assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}");
        }
        assert_eq!(sf.sim_time_s.to_bits(), ss.sim_time_s.to_bits(), "{label}");
        assert_eq!(sf.itl.p99.to_bits(), ss.itl.p99.to_bits(), "{label}");
    }
}

/// The fast paths must not consume per-token model evaluations: the
/// decode-loop proxy count scales with segments, not output length.
#[test]
fn closed_form_eval_count_is_output_length_independent() {
    let mk = |out: usize| {
        let mut cfg = ExperimentConfig::paper_point(
            ModelId::Llama32_1b,
            &[LoraTarget::Q, LoraTarget::V],
            512,
        );
        cfg.output_tokens = out;
        // A calibration value no other test uses gives this test a
        // PRIVATE cached model instance, so the per-instance eval
        // counter cannot race concurrently running tests.
        cfg.calib.nmc_issue_cycles = 5;
        cfg
    };
    let count_evals = |out: usize| -> u64 {
        let cfg = mk(out);
        let sim = Simulator::new(&cfg);
        // build_cached returns the same shared instance the engine uses.
        let model = LayerCostModel::build_cached(&cfg, &sim.mapping().layers[0]);
        let before = model.eval_count();
        let _ = sim.run_sharded_batched(1, 1);
        model.eval_count() - before
    };
    let evals_short = count_evals(16);
    let evals_long = count_evals(2048);
    assert_eq!(
        evals_short, evals_long,
        "closed-form eval count must not scale with output tokens"
    );
    assert!(evals_long <= 8, "closed form consumed {evals_long} evals");
}

/// The sweep driver is deterministic: identical SimReports at any worker
/// count, in input order.
#[test]
fn parallel_sweep_is_bit_deterministic() {
    let grid: Vec<ExperimentConfig> = paper_grid()
        .into_iter()
        .filter(|c| c.model.id == ModelId::Llama32_1b)
        .collect();
    let serial = sweep::run_indexed(1, grid.len(), |i| Simulator::new(&grid[i]).run());
    for jobs in [2usize, 4] {
        let par = sweep::run_indexed(jobs, grid.len(), |i| Simulator::new(&grid[i]).run());
        assert_eq!(par.len(), serial.len());
        for (a, b) in par.iter().zip(&serial) {
            assert_eq!(a.model, b.model, "jobs {jobs}");
            assert_eq!(a.input_tokens, b.input_tokens, "jobs {jobs}");
            assert_bit_identical(a, b, &format!("jobs {jobs}: {} {}", a.model, a.input_tokens));
        }
    }
}
