//! Integration: the LM-head extension — enabling it must add a small,
//! bounded per-token cost and the expected extra CT allocation, without
//! disturbing the paper-mode (head-off) reproduction.

use primal::config::{ExperimentConfig, LoraTarget, ModelId};
use primal::sim::{LmHead, Simulator};

fn cfg(model: ModelId, head: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::paper_point(model, &[LoraTarget::Q, LoraTarget::V], 512);
    c.include_lm_head = head;
    c
}

#[test]
fn head_adds_bounded_itl() {
    for model in [ModelId::Llama32_1b, ModelId::Llama2_13b] {
        let off = Simulator::new(&cfg(model, false)).run();
        let on = Simulator::new(&cfg(model, true)).run();
        assert!(on.itl_ms > off.itl_ms, "{model:?}: head must cost something");
        // ...but no more than ~15% of a decode step (in-network top-k).
        assert!(
            on.itl_ms < off.itl_ms * 1.15,
            "{model:?}: head overhead {:.3} -> {:.3} ms too large",
            off.itl_ms,
            on.itl_ms
        );
        // TTFT unchanged: prefill computes no logits until the last token
        // (the head cost of that single token is inside the first ITL).
        assert!((on.ttft_s - off.ttft_s).abs() / off.ttft_s < 1e-9);
    }
}

#[test]
fn head_allocation_matches_vocab() {
    // 1B has the 128k vocab (4 CTs); 13B the 32k vocab (3 CTs) despite
    // being the bigger model — allocation follows vocab x hidden, not
    // parameter count.
    let h1 = LmHead::build(&cfg(ModelId::Llama32_1b, true));
    let h13 = LmHead::build(&cfg(ModelId::Llama2_13b, true));
    assert_eq!(h1.n_cts, 4);
    assert_eq!(h13.n_cts, 3);
}

#[test]
fn paper_mode_unaffected() {
    // The default config keeps the head off — Table II/III reproduction
    // must not silently shift.
    let c = ExperimentConfig::paper_point(
        ModelId::Llama32_1b,
        &[LoraTarget::Q, LoraTarget::V],
        512,
    );
    assert!(!c.include_lm_head);
}
