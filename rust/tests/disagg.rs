//! Integration: the disaggregated pool tier ([`PoolPlan`]) above the
//! chip tier — the same extension-not-fork discipline the sharded tier
//! established:
//!
//!  1. the degenerate plan collapses **bitwise**: one pool holding all
//!     chips at one pipeline stage reproduces `run_sharded_batched` on
//!     every `SimReport` field, energy bits included;
//!  2. per-layer compute work is conserved across any pool split — the
//!     event-driven energy categories (RRAM/SRAM/scratchpad/DMAC) are
//!     bit-identical however the chips are pooled or staged;
//!  3. KV migration is exactly one chip-mesh transfer per request,
//!     strictly positive for every real split and zero unified;
//!  4. the serving path keeps the fast-forward bit-identity while
//!     admissions (prefill pool) overlap live decode (decode pool);
//!  5. the mirror-blessed engine cycle counts and Table II `--disagg`
//!     drain witnesses hold exactly, including the committed claim that
//!     the 2p+2d split beats symmetric sharding on the prefill-heavy mix.

mod common;

use common::cfg_of;
use primal::config::{ModelId, PolicyKind};
use primal::coordinator::{AdapterId, Request, ServerBuilder};
use primal::mapping::PoolPlan;
use primal::metrics::run_point_disagg_serve;
use primal::noc::ChipMesh;
use primal::sim::{SimReport, Simulator};

/// Field-by-field bit comparison of two reports (the sharded tier's
/// one-chip discipline, extended to the pool tier).
fn assert_reports_bit_identical(a: &SimReport, b: &SimReport, label: &str) {
    assert_eq!(a.model, b.model, "{label}: model");
    assert_eq!(a.lora_label, b.lora_label, "{label}: lora");
    assert_eq!(a.input_tokens, b.input_tokens, "{label}: input");
    assert_eq!(a.output_tokens, b.output_tokens, "{label}: output");
    assert_eq!(a.batch, b.batch, "{label}: batch");
    assert_eq!(a.n_chips, b.n_chips, "{label}: chips");
    assert_eq!(a.srpg, b.srpg, "{label}: srpg");
    assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "{label}: ttft");
    assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "{label}: itl");
    assert_eq!(
        a.throughput_tps.to_bits(),
        b.throughput_tps.to_bits(),
        "{label}: throughput"
    );
    assert_eq!(a.avg_power_w.to_bits(), b.avg_power_w.to_bits(), "{label}: power");
    assert_eq!(
        a.efficiency_tpj.to_bits(),
        b.efficiency_tpj.to_bits(),
        "{label}: efficiency"
    );
    assert_eq!(a.total_cts, b.total_cts, "{label}: cts");
    assert_eq!(a.cts_per_layer, b.cts_per_layer, "{label}: cts/layer");
    assert_eq!(a.total_cycles, b.total_cycles, "{label}: cycles");
    assert_eq!(
        a.total_energy_j.to_bits(),
        b.total_energy_j.to_bits(),
        "{label}: energy"
    );
    assert_eq!(
        a.reprog_stall_cycles, b.reprog_stall_cycles,
        "{label}: reprog stalls"
    );
    assert_eq!(a.itl_first_ms.to_bits(), b.itl_first_ms.to_bits(), "{label}: itl0");
    assert_eq!(a.itl_last_ms.to_bits(), b.itl_last_ms.to_bits(), "{label}: itlN");
    for (name, x, y) in [
        ("rram_j", a.energy.rram_j, b.energy.rram_j),
        ("sram_j", a.energy.sram_j, b.energy.sram_j),
        ("scratchpad_j", a.energy.scratchpad_j, b.energy.scratchpad_j),
        ("router_j", a.energy.router_j, b.energy.router_j),
        ("dmac_j", a.energy.dmac_j, b.energy.dmac_j),
        ("network_j", a.energy.network_j, b.energy.network_j),
        ("retention_j", a.energy.retention_j, b.energy.retention_j),
        ("static_j", a.energy.static_j, b.energy.static_j),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: energy.{name}");
    }
}

// ---- 1. degenerate bitwise collapse ---------------------------------------

#[test]
fn unified_single_stage_bitmatches_run_sharded_on_every_field() {
    for (model, ctx, batch, chips) in [
        (ModelId::Llama32_1b, 1024, 1, 1),
        (ModelId::Llama32_1b, 1024, 2, 2),
        (ModelId::Llama3_8b, 2048, 2, 2),
        (ModelId::Llama2_13b, 2048, 4, 4),
    ] {
        let cfg = cfg_of(model, ctx);
        let sim = Simulator::new(&cfg);
        let pool = PoolPlan::unified(chips, cfg.model.layers);
        let disagg = sim.run_disagg_batched(batch, &pool);
        let sharded = sim.run_sharded_batched(batch, chips);
        let label = format!("{model:?} ctx {ctx} b{batch} x{chips}");
        assert_reports_bit_identical(&disagg, &sharded, &label);
    }
}

// ---- 2. conservation across pool splits -----------------------------------

#[test]
fn compute_event_energy_conserved_across_pool_splits() {
    // The event-driven energy categories count the work actually done —
    // RRAM/DMAC passes, SRAM and scratchpad traffic — per layer and per
    // token, independent of where the layers run. Splitting the chips
    // into pools (or staging the layers) may only move work in time and
    // add *network* transfers, never create or destroy compute.
    let mut cfg = cfg_of(ModelId::Llama32_1b, 512);
    cfg.output_tokens = 32;
    let sim = Simulator::new(&cfg);
    let l = cfg.model.layers;
    let base = sim.run_disagg_batched(2, &PoolPlan::unified(4, l));
    for pool in [
        PoolPlan::split(1, 3, 1, l).expect("1p+3d"),
        PoolPlan::split(2, 2, 1, l).expect("2p+2d"),
        PoolPlan::split(3, 1, 1, l).expect("3p+1d"),
        PoolPlan::split(2, 2, 2, l).expect("2p+2d staged"),
    ] {
        let r = sim.run_disagg_batched(2, &pool);
        let label = format!("{}p+{}d s{}", pool.prefill_chips, pool.decode_chips, pool.stages);
        for (name, x, y) in [
            ("rram_j", base.energy.rram_j, r.energy.rram_j),
            ("sram_j", base.energy.sram_j, r.energy.sram_j),
            ("scratchpad_j", base.energy.scratchpad_j, r.energy.scratchpad_j),
            ("dmac_j", base.energy.dmac_j, r.energy.dmac_j),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: energy.{name} not conserved");
        }
        // Token accounting is conserved too: same tokens, same report
        // identity, whatever the pool shape.
        assert_eq!(r.output_tokens, base.output_tokens, "{label}: output tokens");
        assert_eq!(r.batch, base.batch, "{label}: batch");
    }
}

#[test]
fn stage_layers_partition_the_model_exactly() {
    for model in [ModelId::Llama32_1b, ModelId::Llama3_8b, ModelId::Llama2_13b] {
        let layers = cfg_of(model, 1024).model.layers;
        for stages in [1usize, 2, 4] {
            let plan = PoolPlan::new(4, None, None, stages, layers)
                .expect("4 chips divide 1/2/4 stages");
            assert_eq!(plan.stage_layers.len(), stages, "{model:?} s{stages}");
            assert_eq!(
                plan.stage_layers.iter().sum::<u64>(),
                layers as u64,
                "{model:?} s{stages}: stage layers must cover the model exactly"
            );
            // split_even: stage 0 largest, monotone non-increasing.
            assert!(
                plan.stage_layers.windows(2).all(|w| w[0] >= w[1]),
                "{model:?} s{stages}: {:?}",
                plan.stage_layers
            );
        }
    }
}

// ---- 3. KV migration ------------------------------------------------------

#[test]
fn split_prefill_total_is_base_plus_exactly_one_kv_migration() {
    // With batch 1 and zero output the disaggregated run is pure
    // prefill-then-migrate: its total must decompose EXACTLY into the
    // symmetric run at the prefill pool's width plus one chip-mesh
    // transfer of the request's whole KV — strictly positive for every
    // real split, and absent from the unified plan by construction.
    for (model, ctx) in [(ModelId::Llama32_1b, 512), (ModelId::Llama2_13b, 2048)] {
        let mut cfg = cfg_of(model, ctx);
        cfg.output_tokens = 0;
        let sim = Simulator::new(&cfg);
        let lm0 = &sim.mapping().layers[0];
        let kv_bytes =
            (cfg.input_tokens * lm0.kv_token_bytes) as u64 * cfg.model.layers as u64;
        for (p, d) in [(1usize, 1usize), (2, 2), (3, 1), (1, 3)] {
            let pool = PoolPlan::split(p, d, 1, cfg.model.layers).expect("split");
            let split = sim.run_disagg_batched(1, &pool);
            let base = sim.run_sharded_batched(1, p);
            let migrate = ChipMesh::new(&cfg.shard, p + d).transfer_cycles(kv_bytes);
            let label = format!("{model:?} {p}p+{d}d");
            assert!(migrate > 0, "{label}: migration must be strictly positive");
            assert_eq!(
                split.total_cycles,
                base.total_cycles + migrate,
                "{label}: split prefill != base + one KV transfer"
            );
        }
        // The unified plan pays zero migration: same zero-output run,
        // same chips, bit-identical to the symmetric engine.
        let uni = sim.run_disagg_batched(1, &PoolPlan::unified(4, cfg.model.layers));
        assert_eq!(uni.total_cycles, sim.run_sharded_batched(1, 4).total_cycles);
    }
}

// ---- 4. mirror-blessed engine witnesses -----------------------------------

#[test]
fn mirror_blessed_disagg_cycle_counts() {
    // 13B 2048-in/256-out, batch 4, 2 prefill + 2 decode chips: the
    // closed-batch staircase (and its 2-stage pipelined variant) pinned
    // by `sim_mirror.py`'s operation-exact integers.
    let mut cfg = cfg_of(ModelId::Llama2_13b, 2048);
    cfg.output_tokens = 256;
    let sim = Simulator::new(&cfg);
    let l = cfg.model.layers;
    let single = sim
        .run_disagg_batched(4, &PoolPlan::split(2, 2, 1, l).expect("2p+2d"))
        .total_cycles;
    let staged = sim
        .run_disagg_batched(4, &PoolPlan::split(2, 2, 2, l).expect("2p+2d s2"))
        .total_cycles;
    assert_eq!(single, 13_035_984_698, "2p+2d single-stage");
    assert_eq!(staged, 10_578_215_649, "2p+2d two-stage");
    // Pipelining the pools' layers overlaps the per-request fills, so
    // the staged plan strictly beats the pure tensor split here.
    assert!(staged < single, "pipeline packing must win on this point");
}

// ---- 5. serving: rejections, overlap, and the Table II witnesses ----------

#[test]
fn disagg_serving_rejects_invalid_modes_with_real_errors() {
    let server = |continuous: bool, chunk: Option<usize>, stages: usize| {
        let mut exp = cfg_of(ModelId::Llama32_1b, 256);
        exp.shard.n_chips = 4;
        exp.shard.prefill_chips = Some(2);
        exp.shard.decode_chips = Some(2);
        exp.shard.pipeline_stages = stages;
        ServerBuilder::from_experiment(exp)
            .max_batch(2)
            .continuous(continuous)
            .prefill_chunk(chunk)
            .build()
    };
    let e = server(false, None, 1).err().expect("disagg needs continuous");
    assert!(format!("{e:#}").contains("continuous"), "got: {e:#}");
    let e = server(true, Some(64), 1).err().expect("disagg excludes chunking");
    assert!(format!("{e:#}").contains("chunk"), "got: {e:#}");
    let e = server(true, None, 2).err().expect("serving rejects pipelining");
    assert!(format!("{e:#}").contains("stage"), "got: {e:#}");
    // Contradictory pool flags surface the config validator's message,
    // not a clamp: 2 + 2 != 3.
    let mut exp = cfg_of(ModelId::Llama32_1b, 256);
    exp.shard.n_chips = 3;
    exp.shard.prefill_chips = Some(2);
    exp.shard.decode_chips = Some(2);
    let e = ServerBuilder::from_experiment(exp)
        .max_batch(2)
        .continuous(true)
        .build()
        .err()
        .expect("2p + 2d != 3 chips must fail");
    assert!(format!("{e:#}").contains("!= n_chips"), "got: {e:#}");
    // The valid shape builds.
    assert!(server(true, None, 1).is_ok());
}

#[test]
fn fast_forward_is_invisible_with_overlapped_disagg_admissions() {
    // Staggered arrivals on a 2p+2d server: admissions prefill on the
    // prefill pool while the decode pool steps in-flight slots — the
    // overlap path fast-forwarding must reproduce bit-for-bit.
    let run = |ff: bool| {
        let mut exp = cfg_of(ModelId::Llama32_1b, 256);
        exp.shard.n_chips = 4;
        exp.shard.prefill_chips = Some(2);
        exp.shard.decode_chips = Some(2);
        let mut s = ServerBuilder::from_experiment(exp)
            .max_batch(2)
            .policy_kind(PolicyKind::Fcfs)
            .continuous(true)
            .decode_fast_forward(ff)
            .build()
            .expect("disagg server");
        s.register_adapter(AdapterId(0));
        for i in 0..8u64 {
            s.submit(Request::new(i, AdapterId(0), 256, 24).at(i as f64 * 0.002))
                .expect("submit");
        }
        let results = s.drain(None).expect("drain");
        (results, s.stats())
    };
    let (rf, sf) = run(true);
    let (rs, ss) = run(false);
    assert_eq!(rf.len(), 8);
    assert_eq!(rf.len(), rs.len());
    for (a, b) in rf.iter().zip(&rs) {
        assert_eq!(a.request, b.request, "completion order");
        assert_eq!(a.start_s.to_bits(), b.start_s.to_bits(), "req {}: start", a.request);
        assert_eq!(a.queue_s.to_bits(), b.queue_s.to_bits(), "req {}: queue", a.request);
        assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "req {}: ttft", a.request);
        assert_eq!(a.itl_ms.to_bits(), b.itl_ms.to_bits(), "req {}: itl", a.request);
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "req {}: total", a.request);
        assert_eq!(a.tokens_out, b.tokens_out, "req {}: tokens", a.request);
    }
    assert_eq!(sf.sim_time_s.to_bits(), ss.sim_time_s.to_bits(), "drain time");
    assert_eq!(sf.preemptions, ss.preemptions);
    assert_eq!(sf.kv_page_allocs, ss.kv_page_allocs);
}

#[test]
fn disagg_serve_itl_matches_decode_pool_width_and_sym_baseline() {
    // The decode pool sets the ITL: a 3p+1d split decodes at width 1,
    // so its per-token latency must bit-match the 1-chip continuous
    // server's (the prefill pool only moves admission timing).
    let mut one = cfg_of(ModelId::Llama32_1b, 256);
    one.shard.n_chips = 1;
    let mut split = cfg_of(ModelId::Llama32_1b, 256);
    split.shard.n_chips = 4;
    split.shard.prefill_chips = Some(3);
    split.shard.decode_chips = Some(1);
    let serve = |exp: primal::config::ExperimentConfig| {
        let mut s = ServerBuilder::from_experiment(exp)
            .max_batch(1)
            .continuous(true)
            .build()
            .expect("server");
        s.register_adapter(AdapterId(0));
        s.submit(Request::new(0, AdapterId(0), 256, 16)).expect("submit");
        let r = s.drain(None).expect("drain");
        assert_eq!(r.len(), 1);
        r[0].itl_ms
    };
    assert_eq!(serve(one).to_bits(), serve(split).to_bits(), "decode-width ITL");
}

#[test]
fn table2_disagg_winning_cell_matches_mirror_blessed_drains() {
    // The committed Table II `--disagg` claim: on the prefill-heavy
    // backlog (8 x 2048/256, FCFS, batch 4) the 2p+2d split beats the
    // symmetric 4-chip baseline. Both drains are pinned as truncated-
    // nanosecond witnesses blessed from the mirror.
    let mut cfg = cfg_of(ModelId::Llama2_13b, 2048);
    cfg.shard.n_chips = 4;
    let sym = run_point_disagg_serve(&cfg, 8, 256, 4, None).expect("symmetric cell");
    let dsp = run_point_disagg_serve(&cfg, 8, 256, 4, Some((2, 2))).expect("2p+2d cell");
    assert_eq!(sym.served, 8, "symmetric cell lost requests");
    assert_eq!(dsp.served, 8, "split cell lost requests");
    assert_eq!(sym.preemptions, 0);
    assert_eq!(dsp.preemptions, 0);
    assert_eq!((sym.drain_s * 1e9) as u64, 24_842_102_420, "symmetric drain");
    assert_eq!((dsp.drain_s * 1e9) as u64, 23_552_970_138, "2p+2d drain");
    assert!(
        dsp.drain_s < sym.drain_s,
        "disaggregation must beat symmetric sharding on the prefill-heavy mix"
    );
    assert!(dsp.throughput_tps > sym.throughput_tps);
}
