//! Integration: cross-request KV prefix reuse on the paged pool — the
//! serving-level payoff (TTFT under load), the energy credit, and the
//! refcount discipline under preemption pressure.
//!
//! The bit-level contracts (prefill FLOP conservation, share-0 bit-match
//! against plain continuous mode, fast-forward invisibility) are gated in
//! `tests/scheduling.rs`; this suite exercises the end-to-end behavior a
//! deployment would measure.

mod common;

use common::{exp_1b, p95};
use primal::config::PolicyKind;
use primal::coordinator::{AdapterId, PreambleId, Request, Server, ServerBuilder};
use primal::energy::rram_passes_j;

/// A continuous-mode server with one registered adapter and one
/// single-block preamble (128 of the 256 prompt tokens).
fn prefix_server(batch: usize, pool: Option<usize>) -> Server {
    let mut s = ServerBuilder::from_experiment(exp_1b(256))
        .max_batch(batch)
        .policy_kind(PolicyKind::Fcfs)
        .continuous(true)
        .kv_pool_pages(pool)
        .build()
        .expect("server");
    s.register_adapter(AdapterId(0));
    s.register_preamble(PreambleId(0), vec![0xFEED_FACE]).expect("preamble");
    s
}

/// Effective per-request service time on a batch-2 server: two
/// simultaneous requests, drained, sim time halved. The probe is what
/// lets the load test below self-calibrate its arrival rate instead of
/// hard-coding model-dependent seconds.
fn probe_service_s(shared: bool) -> f64 {
    let mut s = prefix_server(2, None);
    for i in 0..2u64 {
        let mut req = Request::new(i, AdapterId(0), 256, 8);
        if shared {
            req = req.with_preamble(PreambleId(0));
        }
        s.submit(req).expect("submit");
    }
    assert_eq!(s.drain(None).expect("drain").len(), 2);
    s.stats().sim_time_s / 2.0
}

/// Drain `n` requests arriving every `gap_s` seconds, the leading
/// `shared` of them carrying the preamble (contiguous, so each sharer's
/// admission overlaps the previous holder and actually hits). Returns the
/// p95 of the *arrival-relative* first-token latency (queue + TTFT — the
/// time a user waits, which is what queue buildup compounds) plus stats.
fn loaded_run(
    n: usize,
    shared: usize,
    gap_s: f64,
) -> (f64, primal::coordinator::ServerStats) {
    let mut s = prefix_server(2, None);
    for i in 0..n as u64 {
        let mut req = Request::new(i, AdapterId(0), 256, 8).at(i as f64 * gap_s);
        if (i as usize) < shared {
            req = req.with_preamble(PreambleId(0));
        }
        s.submit(req).expect("submit");
    }
    let results = s.drain(None).expect("drain");
    assert_eq!(results.len(), n, "conservation");
    let mut first_token: Vec<f64> = results.iter().map(|r| r.queue_s + r.ttft_s).collect();
    (p95(&mut first_token), s.stats())
}

#[test]
fn shared_prefixes_cut_tail_ttft_superlinearly_under_load() {
    // Arrivals paced between the shared and plain service rates: the
    // plain server cannot keep up, so its queue — and with it the p95
    // arrival-to-first-token latency — grows with every arrival; the
    // fully shared run stays ahead of the clock and its p95 hovers at one
    // service time. The payoff is therefore SUPERLINEAR in the hit rate:
    // skipping ~half of each prefill under these arrivals cuts the tail
    // by far more than half, because every skipped block also shortens
    // every later arrival's queue wait.
    let s_plain = probe_service_s(false);
    let s_shared = probe_service_s(true);
    assert!(
        s_shared < s_plain,
        "shared prefill must be cheaper: {s_shared} vs {s_plain}"
    );
    let gap = 0.65 * s_plain + 0.35 * s_shared;
    let (p95_plain, st0) = loaded_run(32, 0, gap);
    let (p95_half, _) = loaded_run(32, 16, gap);
    let (p95_full, st1) = loaded_run(32, 32, gap);
    assert_eq!(st0.prefix_admissions, 0);
    assert!(st1.prefix_admissions >= 32, "every admission carried the preamble");
    assert!(st1.prefix_hit_blocks > 0, "overlapping sharers must hit");
    assert!(
        p95_full < p95_half && p95_half < p95_plain,
        "p95 TTFT must fall with the share: {p95_plain:.4} -> {p95_half:.4} -> {p95_full:.4}"
    );
    let drop_full = (p95_plain - p95_full) / p95_plain;
    assert!(
        drop_full > 0.5,
        "near saturation, sharing one of two prefill blocks must cut the \
         p95 tail by MORE than the work it removes (got {:.1}%)",
        drop_full * 100.0
    );
}

#[test]
fn prefix_energy_credit_matches_the_ledger_conversion() {
    // The "RRAM passes saved" credit must convert to joules through the
    // exact same constant the energy ledger posts dynamic passes with —
    // bit-for-bit, so the two accountings can never drift apart.
    let mut s = prefix_server(4, None);
    for i in 0..8u64 {
        s.submit(Request::new(i, AdapterId(0), 256, 16).with_preamble(PreambleId(0)))
            .expect("submit");
    }
    s.drain(None).expect("drain");
    let st = s.stats();
    assert!(st.prefix_rram_passes_saved > 0, "hits must bank analog passes");
    let expect = rram_passes_j(st.prefix_rram_passes_saved, &exp_1b(256).calib);
    assert_eq!(
        st.prefix_energy_saved_j.to_bits(),
        expect.to_bits(),
        "energy credit must share the ledger's conversion bit-for-bit"
    );
    assert!(st.prefix_energy_saved_j > 0.0);
}

#[test]
fn preemption_pressure_never_strands_shared_nodes() {
    // A page famine over preambled requests: LIFO preemption releases the
    // victim's prefix references (but never frees a node another sharer
    // still holds), re-admission re-interns, and at drain the cache is
    // empty with interns == releases even though admissions repeated.
    let mut s = prefix_server(4, Some(7));
    for i in 0..8u64 {
        s.submit(
            Request::new(i, AdapterId(0), 256, 96)
                .at(i as f64 * 0.001)
                .with_preamble(PreambleId(0)),
        )
        .expect("submit");
    }
    let results = s.drain(None).expect("drain");
    assert_eq!(results.len(), 8, "every request completes despite the famine");
    let st = s.stats();
    assert!(st.preemptions > 0, "the famine must preempt");
    assert!(
        st.prefix_admissions > 8,
        "preempted sharers re-intern on re-admission: {} admissions",
        st.prefix_admissions
    );
    assert_eq!(st.prefix_interns, st.prefix_releases, "refcount conservation");
    assert_eq!(st.prefix_nodes_created, st.prefix_nodes_freed, "node lifecycle");
    assert_eq!(st.prefix_live_nodes, 0, "cache empty at drain");
    assert_eq!(st.kv_page_allocs, st.kv_page_frees, "page conservation");
    assert_eq!(st.kv_used_pages, 0);
}

#[test]
fn prefix_affinity_starvation_bound_limits_minority_queue_delay() {
    // The prefix-affinity twin of scheduling.rs's adapter-affinity
    // starvation test: eight requests sharing one preamble and one
    // carrying a different preamble, all at t=0 on one adapter. Unbounded
    // affinity rides the majority chain to the end; a run bound of 2
    // regroups onto the minority after two same-preamble admissions.
    let run = |max_run_len: Option<usize>| {
        let mut exp = exp_1b(256);
        exp.serving.affinity_max_run_len = max_run_len;
        let mut s = ServerBuilder::from_experiment(exp)
            .max_batch(1)
            .policy_kind(PolicyKind::PrefixAffinity)
            .continuous(true)
            .build()
            .unwrap();
        s.register_adapter(AdapterId(0));
        s.register_preamble(PreambleId(0), vec![0xAA]).unwrap();
        s.register_preamble(PreambleId(1), vec![0xBB]).unwrap();
        for i in 0..8u64 {
            s.submit(Request::new(i, AdapterId(0), 256, 8).with_preamble(PreambleId(0)))
                .unwrap();
        }
        s.submit(Request::new(8, AdapterId(0), 256, 8).with_preamble(PreambleId(1)))
            .unwrap();
        let res = s.drain(None).unwrap();
        assert_eq!(res.len(), 9);
        let pos = res.iter().position(|r| r.request == 8).unwrap();
        let queue = res.iter().find(|r| r.request == 8).unwrap().queue_s;
        (pos, queue)
    };
    let (pos_unbounded, q_unbounded) = run(None);
    let (pos_bounded, q_bounded) = run(Some(2));
    assert_eq!(
        pos_unbounded, 8,
        "unbounded prefix affinity starves the minority preamble to the end"
    );
    assert!(
        pos_bounded <= 2,
        "run bound 2 must serve the minority within one bounded run, got {pos_bounded}"
    );
    assert!(
        q_bounded < q_unbounded * 0.5,
        "bounded queue delay {q_bounded} not well below unbounded {q_unbounded}"
    );
}

#[test]
fn two_block_chains_share_partially_with_sibling_preambles() {
    // Two preambles sharing a root block: interleaved admissions build a
    // two-node tree once, and the sibling's first admission still hits
    // the shared root while missing its own leaf.
    let mut s = ServerBuilder::from_experiment(exp_1b(256))
        .max_batch(4)
        .continuous(true)
        .build()
        .expect("server");
    s.register_adapter(AdapterId(0));
    s.register_preamble(PreambleId(0), vec![0xAB, 0x01]).expect("preamble 0");
    s.register_preamble(PreambleId(1), vec![0xAB, 0x02]).expect("preamble 1");
    for i in 0..4u64 {
        s.submit(
            Request::new(i, AdapterId(0), 256, 16)
                .with_preamble(PreambleId((i % 2) as u32)),
        )
        .expect("submit");
    }
    let results = s.drain(None).expect("drain");
    assert_eq!(results.len(), 4);
    let st = s.stats();
    assert_eq!(st.prefix_admissions, 4);
    // Request 0 interns [root, leaf0] cold (2 misses). Request 1 hits the
    // root, misses leaf1. Requests 2 and 3 hit both blocks of their
    // chain. Total: 2 + 1 + 0 + 0 = 3 misses, 0 + 1 + 2 + 2 = 5 hits.
    assert_eq!(st.prefix_miss_blocks, 3, "root interned once, one leaf each");
    assert_eq!(st.prefix_hit_blocks, 5, "sibling reuses the shared root");
    assert_eq!(st.prefix_nodes_created, 3, "one root + two leaves");
    assert_eq!(st.prefix_nodes_freed, 3);
    assert_eq!(st.prefix_live_nodes, 0);
    assert_eq!(st.kv_page_allocs, st.kv_page_frees);
}
