//! Integration gates for the sweep costing cache.
//!
//! Two commitments:
//!  * an **incremental rerun** of a grid the process has already costed
//!    rebuilds nothing — zero mapping / layer-model / prefill / reprogram
//!    builds, zero generated programs — and replays every report
//!    bit-for-bit, serial and at `--jobs 4`;
//!  * the dual-FNV cost key collides **only within a structural class**:
//!    the swept axes (ctx, batch) never move it, while model, LoRA
//!    targets, and chip width always separate it (chips and `ModelId`
//!    ride along as structural fields, the hash halves must each
//!    discriminate the rest on their own).

use primal::config::{ExperimentConfig, LoraTarget, ModelId};
use primal::mapping::map_model;
use primal::sim::registry::cost_key_fingerprint;
use primal::sim::{sweep, RegistryStats, SimReport, Simulator};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

/// The registry counters are process-wide and both tests touch them (or
/// the caches behind them); serialize so parallel test threads cannot
/// smear a counter delta.
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Every numeric report field as raw bits: integers widened, f64s via
/// `to_bits` so `-0.0` vs `0.0` or a NaN fails instead of passing `==`.
fn numeric_bits(r: &SimReport) -> Vec<u64> {
    vec![
        r.input_tokens as u64,
        r.output_tokens as u64,
        r.batch as u64,
        r.n_chips as u64,
        u64::from(r.srpg),
        r.ttft_s.to_bits(),
        r.itl_ms.to_bits(),
        r.throughput_tps.to_bits(),
        r.avg_power_w.to_bits(),
        r.efficiency_tpj.to_bits(),
        r.total_cts as u64,
        r.cts_per_layer as u64,
        r.total_cycles,
        r.total_energy_j.to_bits(),
        r.energy.rram_j.to_bits(),
        r.energy.sram_j.to_bits(),
        r.energy.scratchpad_j.to_bits(),
        r.energy.router_j.to_bits(),
        r.energy.dmac_j.to_bits(),
        r.energy.network_j.to_bits(),
        r.energy.retention_j.to_bits(),
        r.energy.static_j.to_bits(),
        r.reprog_stall_cycles,
        r.itl_first_ms.to_bits(),
        r.itl_last_ms.to_bits(),
    ]
}

#[test]
fn incremental_rerun_rebuilds_nothing_and_replays_bit_identically() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // 1B with LoRA on Q only: a structural class nothing else in this
    // binary simulates, so the cold pass sees virgin caches.
    let mut grid: Vec<(usize, usize, usize)> = Vec::new();
    for ctx in [256usize, 512] {
        for batch in [1usize, 4] {
            for chips in [1usize, 2] {
                grid.push((ctx, batch, chips));
            }
        }
    }
    let point = |i: usize| -> SimReport {
        let (ctx, batch, chips) = grid[i];
        let cfg = ExperimentConfig::paper_point(ModelId::Llama32_1b, &[LoraTarget::Q], ctx);
        Simulator::new(&cfg).run_sharded_batched(batch, chips)
    };
    let (cold_reports, cold) = sweep::run_cached(1, grid.len(), &point);
    // Serial cold pass over the 8 points: one mapping, two layer models
    // (widths 1 and 2), 8 prefill block costs (4 kv points x 2 widths),
    // one reprogram template, 29 generated programs (2 x 10 decode
    // samples + 8 prefill + 1 reprogram), and 4 window-memo inserts
    // (keys (256,256) and (512,512) on each width's memo).
    assert_eq!(
        cold,
        RegistryStats {
            mapping_hits: 7,
            mapping_builds: 1,
            layer_model_hits: 10,
            layer_model_builds: 2,
            prefill_hits: 16,
            prefill_builds: 8,
            reprog_hits: 7,
            reprog_builds: 1,
            programs_generated: 29,
            window_hits: 8,
            window_inserts: 4,
            window_full_skips: 0,
        },
        "cold pass drifted from the structural replay of the grid"
    );
    // Warm reruns are all-hits at every worker width — and because every
    // cache is keyed insert-once, the counter delta itself is exact even
    // at jobs 4.
    let expect_warm = RegistryStats {
        mapping_hits: 8,
        mapping_builds: 0,
        layer_model_hits: 12,
        layer_model_builds: 0,
        prefill_hits: 24,
        prefill_builds: 0,
        reprog_hits: 8,
        reprog_builds: 0,
        programs_generated: 0,
        window_hits: 12,
        window_inserts: 0,
        window_full_skips: 0,
    };
    for jobs in [1usize, 4] {
        let (warm_reports, warm) = sweep::run_cached(jobs, grid.len(), &point);
        assert_eq!(warm, expect_warm, "warm pass at jobs {jobs} rebuilt something");
        assert_eq!(warm.total_builds(), 0);
        for (i, (c, w)) in cold_reports.iter().zip(&warm_reports).enumerate() {
            let at = grid[i];
            assert_eq!(c.model, w.model, "jobs {jobs}, point {at:?}");
            assert_eq!(c.lora_label, w.lora_label, "jobs {jobs}, point {at:?}");
            assert_eq!(
                numeric_bits(c),
                numeric_bits(w),
                "jobs {jobs}, point {at:?}: warm report not bit-identical"
            );
            assert_eq!(c.trace.events, w.trace.events, "jobs {jobs}, point {at:?}");
        }
    }
}

#[test]
fn cost_keys_collide_only_within_a_structural_class() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let models = [ModelId::Llama32_1b, ModelId::Llama3_8b, ModelId::Llama2_13b];
    let target_sets: [&[LoraTarget]; 2] = [&[LoraTarget::Q], &[LoraTarget::Q, LoraTarget::V]];
    // 3 models x 2 LoRA sets x ctx {1024, 2048} x chips {1,2,4,8} x
    // batch {1,4} = 96 grid points, bucketed by structural class.
    // `map_model` (uncached) keeps the shared registry untouched so the
    // incremental-rerun test stays cold on its own class in either order.
    let mut by_class: BTreeMap<(usize, usize, usize), BTreeSet<(u64, u64, ModelId, usize)>> =
        BTreeMap::new();
    let mut points = 0usize;
    for (mi, &model) in models.iter().enumerate() {
        for (ti, &targets) in target_sets.iter().enumerate() {
            for ctx in [1024usize, 2048] {
                let cfg = ExperimentConfig::paper_point(model, targets, ctx);
                let mapping = map_model(&cfg);
                let lm0 = &mapping.layers[0];
                for chips in [1usize, 2, 4, 8] {
                    for _batch in [1usize, 4] {
                        let key = cost_key_fingerprint(&cfg, lm0, chips);
                        by_class.entry((mi, ti, chips)).or_default().insert(key);
                        points += 1;
                    }
                }
            }
        }
    }
    assert_eq!(points, 96);
    assert_eq!(by_class.len(), 24, "3 models x 2 LoRA sets x 4 chip widths");
    // The swept axes never move the key: one key per class across both
    // ctx values and both batch sizes.
    for (class, set) in &by_class {
        assert_eq!(set.len(), 1, "class {class:?} key moved across ctx/batch");
    }
    // Across classes every key is distinct; chips reaches the key as a
    // structural field (the hash halves are shared across widths), and
    // each FNV half must separate the 6 (model, LoRA) classes on its own.
    let all: BTreeSet<(u64, u64, ModelId, usize)> =
        by_class.values().flatten().copied().collect();
    assert_eq!(all.len(), 24, "cross-class key collision");
    let h1s: BTreeSet<u64> = all.iter().map(|k| k.0).collect();
    let h2s: BTreeSet<u64> = all.iter().map(|k| k.1).collect();
    assert_eq!(h1s.len(), 6, "h1 must separate the (model, LoRA) classes");
    assert_eq!(h2s.len(), 6, "h2 must separate the (model, LoRA) classes");
    for key in &all {
        assert!([1usize, 2, 4, 8].contains(&key.3), "chip width lost from the key");
    }
}

#[test]
fn run_cached_on_an_empty_grid_is_a_no_op() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (results, delta) = sweep::run_cached(4, 0, |_| 0u64);
    assert!(results.is_empty());
    assert_eq!(delta, RegistryStats::default());
}
