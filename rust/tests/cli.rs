//! Negative-path CLI contract: contradictory or malformed flags must
//! fail with the real validation message on stderr and a non-zero exit
//! code — never a panic, and never a silent clamp into a runnable shape.
//!
//! Exit-code convention (checked per case): flag-syntax errors route
//! through `usage()` (exit 2); semantic config errors surface after
//! parsing (exit 1). Every case also asserts the process did not panic.

use std::process::{Command, Output};

/// Run the `primal` binary with `args`, capturing both streams.
fn primal(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_primal"))
        .args(args)
        .output()
        .expect("spawn primal binary")
}

/// Assert a failed invocation: exact exit code, the real error message
/// on stderr, and no panic anywhere in the output.
fn assert_fails(args: &[&str], exit: i32, needle: &str) {
    let out = primal(args);
    let stderr = String::from_utf8_lossy(&out.stderr);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        !stderr.contains("panicked at") && !stdout.contains("panicked at"),
        "primal {args:?} panicked:\n{stderr}"
    );
    assert_eq!(
        out.status.code(),
        Some(exit),
        "primal {args:?}: expected exit {exit}, got {:?}\nstderr:\n{stderr}",
        out.status.code()
    );
    assert!(
        stderr.contains(needle),
        "primal {args:?}: stderr missing {needle:?}:\n{stderr}"
    );
}

#[test]
fn simulate_rejects_zero_chips_with_the_validate_message() {
    // `--chips 0` is a config error `validate()` reports — not a clamp
    // to 1 chip, and not a panic in the sharding arithmetic.
    assert_fails(
        &["simulate", "--model", "1b", "--chips", "0"],
        1,
        "config: shard.n_chips must be >= 1",
    );
}

#[test]
fn simulate_rejects_pool_splits_that_do_not_sum_to_chips() {
    assert_fails(
        &[
            "simulate", "--model", "1b", "--chips", "3", "--prefill-chips", "2",
            "--decode-chips", "2",
        ],
        1,
        "prefill_chips 2 + decode_chips 2 != n_chips 3",
    );
}

#[test]
fn simulate_rejects_a_lone_pool_flag() {
    // One pool flag without the other is ambiguous — setting only the
    // prefill side must not default the decode side into existence.
    assert_fails(
        &["simulate", "--model", "1b", "--chips", "4", "--prefill-chips", "2"],
        1,
        "prefill_chips and decode_chips must be set together",
    );
}

#[test]
fn simulate_rejects_an_empty_pool() {
    assert_fails(
        &[
            "simulate", "--model", "1b", "--chips", "4", "--prefill-chips", "0",
            "--decode-chips", "4",
        ],
        1,
        "disaggregated pools need >= 1 chip each",
    );
}

#[test]
fn report_rejects_zero_chips() {
    assert_fails(
        &["report", "--table", "2", "--chips", "0"],
        1,
        "--chips expects a count >= 1",
    );
}

#[test]
fn jobs_over_the_worker_ceiling_is_a_hard_error_not_a_clamp() {
    // 65 workers exceeds MAX_JOBS = 64: the sweep driver refuses with
    // the requested number in the message instead of clamping quietly.
    assert_fails(
        &["report", "--table", "2", "--jobs", "65"],
        2,
        "--jobs 65 exceeds the 64-worker ceiling",
    );
}

#[test]
fn serve_rejects_non_numeric_and_non_finite_rates() {
    // Both a parse failure and a successfully-parsed infinity must die
    // on the same guard: inf would silently poison every arrival time.
    assert_fails(
        &["serve", "--model", "1b", "--rate", "abc"],
        2,
        "--rate expects a finite, non-negative req/s value, got 'abc'",
    );
    assert_fails(
        &["serve", "--model", "1b", "--rate", "inf"],
        2,
        "--rate expects a finite, non-negative req/s value, got 'inf'",
    );
    assert_fails(
        &["serve", "--model", "1b", "--rate", "-1"],
        2,
        "--rate expects a finite, non-negative req/s value, got '-1'",
    );
}

#[test]
fn serve_rejects_prefix_shares_outside_the_unit_interval() {
    assert_fails(
        &["serve", "--model", "1b", "--prefix-share", "1.5"],
        2,
        "--prefix-share expects a fraction in [0, 1], got '1.5'",
    );
    assert_fails(
        &["serve", "--model", "1b", "--prefix-share", "-0.1"],
        2,
        "--prefix-share expects a fraction in [0, 1], got '-0.1'",
    );
}

#[test]
fn serve_rejects_zero_chips_and_zero_seeds() {
    assert_fails(
        &["serve", "--model", "1b", "--chips", "0"],
        2,
        "--chips expects a count >= 1",
    );
    assert_fails(
        &["serve", "--model", "1b", "--seeds", "0"],
        2,
        "--seeds expects a count >= 1",
    );
}

#[test]
fn serve_disagg_without_continuous_fails_server_construction() {
    // The pools overlap prefill admission with decode stepping — that
    // only exists in continuous mode, so the builder refuses up front
    // rather than serving a silently-symmetric configuration.
    assert_fails(
        &[
            "serve", "--model", "1b", "--requests", "2", "--chips", "4",
            "--prefill-chips", "2", "--decode-chips", "2",
        ],
        1,
        "continuous",
    );
}

#[test]
fn serve_disagg_split_must_sum_to_chips() {
    assert_fails(
        &[
            "serve", "--model", "1b", "--requests", "2", "--continuous", "--chips",
            "3", "--prefill-chips", "2", "--decode-chips", "2",
        ],
        1,
        "prefill_chips 2 + decode_chips 2 != n_chips 3",
    );
}

#[test]
fn malformed_numeric_flags_report_the_offending_value() {
    assert_fails(
        &["simulate", "--model", "1b", "--chips", "two"],
        2,
        "--chips expects a number, got 'two'",
    );
}

#[test]
fn a_valid_invocation_still_succeeds() {
    // Positive control: the negative paths above must not have made the
    // happy path unreachable.
    let out = primal(&["simulate", "--model", "1b", "--ctx", "128"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr:\n{stderr}");
    assert!(stdout.contains("model"), "report header missing:\n{stdout}");
}
