//! End-to-end serving driver (the repo's E2E validation workload).
//!
//! ```bash
//! make artifacts && cargo run --release --example llama_serving
//! ```
//!
//! Exercises the full three-layer stack on a real small workload:
//!
//!  1. the **PJRT runtime** loads the AOT-compiled JAX/Pallas modules
//!     (HLO text produced once by `make artifacts`), compiles them on the
//!     CPU PJRT client, and validates them against the stored golden
//!     vectors — proving the request path executes real numerics with no
//!     Python anywhere;
//!  2. the **serving coordinator** admits a multi-task request mix
//!     (three LoRA adapters, Poisson arrivals) through the event-driven
//!     `ServerBuilder` API — first in the paper's serial batch-1 FCFS
//!     mode with per-request token streams, then batched (`max_batch 4`)
//!     under each scheduling policy to show what adapter-affinity
//!     admission buys in SRPG swaps and throughput, and finally with
//!     chunked prefill (`prefill_chunk 128`) on a prefill-heavy burst to
//!     show the in-flight stall and tail-ITL reduction;
//!  3. the **cycle simulator** provides the timing for every phase, so
//!     the reported TTFT/ITL/throughput are the paper's Table II/III
//!     quantities for this workload.
//!
//! The run is recorded in EXPERIMENTS.md ("E2E serving").

use primal::config::{ExperimentConfig, LoraTarget, ModelId, PolicyKind};
use primal::coordinator::{
    AdapterId, FunctionalMode, Request, RequestResult, Server, ServerBuilder,
};
use primal::runtime::{default_artifacts_dir, GoldenRuntime};
use primal::util::Rng;
use std::sync::mpsc;

fn paper_cfg() -> ExperimentConfig {
    ExperimentConfig::paper_point(
        ModelId::Llama32_1b,
        &[LoraTarget::Q, LoraTarget::V],
        512,
    )
}

/// A task-skewed Poisson request mix: consecutive same-task requests hit
/// the resident adapter; task switches pay an SRPG reprogramming pass.
fn request_mix(n: usize) -> Vec<Request> {
    let mut rng = Rng::new(42);
    let mut task = 0u32;
    let mut arrival = 0.0;
    let mut reqs = Vec::new();
    for i in 0..n as u64 {
        if rng.f64() < 0.4 {
            task = rng.range(0, 3) as u32;
        }
        arrival += rng.exponential(0.05); // ~20 s mean inter-arrival
        reqs.push(
            Request::new(i, AdapterId(task), 256 + rng.range(0, 256), 64).at(arrival),
        );
    }
    reqs
}

fn serve(
    functional: FunctionalMode,
    max_batch: usize,
    policy: PolicyKind,
    reqs: &[Request],
    stream: bool,
) -> primal::util::error::Result<(Server, usize)> {
    let mut server = ServerBuilder::from_experiment(paper_cfg())
        .functional(functional)
        .artifacts_dir(default_artifacts_dir())
        .max_batch(max_batch)
        .policy_kind(policy)
        .build()?;
    for a in 0..3u32 {
        server.register_adapter(AdapterId(a));
    }
    for r in reqs {
        server.submit(r.clone())?;
    }
    let n_tokens = if stream {
        let (tx, rx) = mpsc::channel();
        let results = server.drain(Some(&tx))?;
        drop(tx);
        let tokens: Vec<_> = rx.iter().collect();
        println!("  req  task  swap  queue_s  ttft_s  itl_ms  golden_ms");
        for r in &results {
            println!(
                "  {:>3}  {:>4}  {:>4}  {:>7.3}  {:>6.3}  {:>6.3}  {:>8.1}",
                r.request,
                r.adapter.0,
                if r.swap { "yes" } else { "-" },
                r.queue_s,
                r.ttft_s,
                r.itl_ms,
                r.golden_exec_ms.unwrap_or(0.0),
            );
        }
        // Sanity: the stream carried every generated token.
        let expect: usize = results.iter().map(|r| r.tokens_out).sum();
        assert_eq!(tokens.len(), expect);
        tokens.len()
    } else {
        let results = server.drain(None)?;
        results.iter().map(|r| r.tokens_out).sum()
    };
    Ok((server, n_tokens))
}

fn main() -> primal::util::error::Result<()> {
    // ---- 1. functional validation via PJRT ------------------------------
    // Skips gracefully when `artifacts/` has not been built (or when the
    // crate was built without the `xla` feature): the serving layers below
    // still run in timing-only mode.
    let artifacts = default_artifacts_dir();
    let mut functional = FunctionalMode::TimingOnly;
    if !primal::runtime::execution_supported() {
        println!(
            "== golden execution unavailable (hermetic/stub backend); serving in \
             timing-only mode =="
        );
    } else if artifacts.join("manifest.json").exists() {
        println!("== golden-model validation ({}) ==", artifacts.display());
        let rt = GoldenRuntime::open(&artifacts)?;
        for r in rt.validate_all()? {
            println!(
                "  {:>14}: {} (max abs err {:.2e}, {:.1} ms)",
                r.module,
                if r.passed { "PASS" } else { "FAIL" },
                r.max_abs_err,
                r.exec_ms
            );
            assert!(r.passed, "golden validation failed for {}", r.module);
        }
        functional = FunctionalMode::Golden;
    } else {
        println!(
            "== artifacts not built (run `make artifacts`); serving in timing-only mode =="
        );
    }

    let reqs = request_mix(16);

    // ---- 2. the paper's serial model, event-driven ----------------------
    println!("\n== serving Llama 3.2 1B, 3 LoRA tasks, 16 requests (batch 1, FCFS) ==");
    let (server, n_tokens) = serve(functional, 1, PolicyKind::Fcfs, &reqs, true)?;
    let s = server.stats();
    println!(
        "\n  served {} requests / {} tokens in {:.2} simulated s \
         ({:.1} tok/s sustained)",
        s.served,
        s.total_tokens,
        s.sim_time_s,
        s.total_tokens as f64 / s.sim_time_s,
    );
    println!(
        "  adapter swaps {}, hits {} — hits skip reprogramming entirely",
        s.adapter_swaps, s.adapter_hits
    );
    println!(
        "  TTFT p50/p95/p99: {:.3}/{:.3}/{:.3} s; queue p95 {:.3} s",
        s.ttft.p50, s.ttft.p95, s.ttft.p99, s.queue.p95
    );
    println!("  token stream: {n_tokens} events, monotone per request");

    // ---- 3. batched decode under each scheduling policy ------------------
    // Same mix, arrivals collapsed to t=0: with the whole backlog visible
    // up front, affinity provably pays at most one SRPG pass per task.
    let backlog: Vec<Request> = reqs.iter().map(|r| r.clone().at(0.0)).collect();
    println!("\n== same mix as a t=0 backlog, max_batch 4, policy comparison ==");
    println!("  policy              swaps   tok/s   TTFT p95   queue p95");
    let mut rows = Vec::new();
    for policy in [
        PolicyKind::Fcfs,
        PolicyKind::AdapterAffinity,
        PolicyKind::ShortestJobFirst,
    ] {
        let (server, _) = serve(FunctionalMode::TimingOnly, 4, policy, &backlog, false)?;
        let s = server.stats();
        let tps = s.total_tokens as f64 / s.sim_time_s;
        println!(
            "  {:<18} {:>6}  {:>6.1}  {:>8.3}  {:>9.3}",
            policy.name(),
            s.adapter_swaps,
            tps,
            s.ttft.p95,
            s.queue.p95
        );
        rows.push((policy, s.adapter_swaps, tps));
    }
    let fcfs = rows[0];
    let affinity = rows[1];
    assert!(
        affinity.1 <= fcfs.1,
        "adapter-affinity must not swap more than FCFS"
    );
    println!(
        "\n  adapter-affinity amortizes SRPG reprogramming: {} swaps vs {} \
         under FCFS on the same trace",
        affinity.1, fcfs.1
    );

    // ---- 4. chunked prefill vs monolithic admission ----------------------
    // A prefill-heavy burst (512-token prompts, 4-token outputs) is the
    // regime where monolithic admission hurts most: every new prompt
    // occupies all CT groups and stalls the in-flight decode batch for
    // the whole prefill. Chunking the prefill into 128-token pieces
    // interleaved with decode steps caps each stall at a chunk makespan.
    println!("\n== chunked prefill, prefill-heavy burst (512/4, batch 4, affinity) ==");
    println!("  admission          mean stall   p95 ITL      tok/s");
    let chunked_run = |chunk: Option<usize>| -> primal::util::error::Result<(f64, f64, f64)> {
        let mut server = ServerBuilder::from_experiment(paper_cfg())
            .max_batch(4)
            .policy_kind(PolicyKind::AdapterAffinity)
            .prefill_chunk(chunk)
            .build()?;
        for a in 0..3u32 {
            server.register_adapter(AdapterId(a));
        }
        for i in 0..18u64 {
            server.submit(Request::new(i, AdapterId((i % 3) as u32), 512, 4))?;
        }
        let results: Vec<RequestResult> = server.drain(None)?;
        let mean_stall =
            results.iter().map(|r| r.stall_s).sum::<f64>() / results.len() as f64;
        let st = server.stats();
        Ok((mean_stall, st.itl.p95, st.total_tokens as f64 / st.sim_time_s))
    };
    let (stall_mono, p95_mono, tps_mono) = chunked_run(None)?;
    let (stall_chunk, p95_chunk, tps_chunk) = chunked_run(Some(128))?;
    println!(
        "  {:<16} {:>8.4} s {:>8.2} ms {:>9.1}",
        "monolithic", stall_mono, p95_mono, tps_mono
    );
    println!(
        "  {:<16} {:>8.4} s {:>8.2} ms {:>9.1}",
        "chunked (128)", stall_chunk, p95_chunk, tps_chunk
    );
    assert!(
        stall_chunk < stall_mono && p95_chunk < p95_mono,
        "chunked prefill must cut stall and tail ITL on the prefill-heavy burst"
    );
    println!(
        "  chunking caps in-flight stalls at a chunk makespan: {:.1}x lower \
         mean stall, {:.1}x lower p95 ITL",
        stall_mono / stall_chunk,
        p95_mono / p95_chunk
    );

    println!("\nE2E OK — all layers composed (PJRT numerics + coordinator + simulator)");
    Ok(())
}
