//! End-to-end serving driver (the repo's E2E validation workload).
//!
//! ```bash
//! make artifacts && cargo run --release --example llama_serving
//! ```
//!
//! Exercises the full three-layer stack on a real small workload:
//!
//!  1. the **PJRT runtime** loads the AOT-compiled JAX/Pallas modules
//!     (HLO text produced once by `make artifacts`), compiles them on the
//!     CPU PJRT client, and validates them against the stored golden
//!     vectors — proving the request path executes real numerics with no
//!     Python anywhere;
//!  2. the **serving coordinator** admits a multi-task request mix
//!     (three LoRA adapters, Poisson-ish arrivals), swapping adapters via
//!     SRPG-pipelined reprogramming, and streams tokens per request;
//!  3. the **cycle simulator** provides the timing for every phase, so
//!     the reported TTFT/ITL/throughput are the paper's Table II/III
//!     quantities for this workload.
//!
//! The run is recorded in EXPERIMENTS.md ("E2E serving").

use primal::config::{ExperimentConfig, LoraTarget, ModelId};
use primal::coordinator::{
    AdapterId, FunctionalMode, Request, Server, ServerConfig,
};
use primal::runtime::{default_artifacts_dir, GoldenRuntime};
use primal::util::Rng;
use std::sync::mpsc;

fn main() -> primal::util::error::Result<()> {
    // ---- 1. functional validation via PJRT ------------------------------
    // Skips gracefully when `artifacts/` has not been built (or when the
    // crate was built without the `xla` feature): the serving layers below
    // still run in timing-only mode.
    let artifacts = default_artifacts_dir();
    let mut functional = FunctionalMode::TimingOnly;
    if !primal::runtime::execution_supported() {
        println!("== built without the `xla` feature; serving in timing-only mode ==");
    } else if artifacts.join("manifest.json").exists() {
        println!("== golden-model validation ({}) ==", artifacts.display());
        let rt = GoldenRuntime::open(&artifacts)?;
        for r in rt.validate_all()? {
            println!(
                "  {:>14}: {} (max abs err {:.2e}, {:.1} ms)",
                r.module,
                if r.passed { "PASS" } else { "FAIL" },
                r.max_abs_err,
                r.exec_ms
            );
            assert!(r.passed, "golden validation failed for {}", r.module);
        }
        functional = FunctionalMode::Golden;
    } else {
        println!(
            "== artifacts not built (run `make artifacts`); serving in timing-only mode =="
        );
    }

    // ---- 2. serving coordinator ------------------------------------------
    println!("\n== serving Llama 3.2 1B, 3 LoRA tasks, 12 requests ==");
    let cfg = ExperimentConfig::paper_point(
        ModelId::Llama32_1b,
        &[LoraTarget::Q, LoraTarget::V],
        512,
    );
    let mut server = Server::new(ServerConfig {
        experiment: cfg,
        functional,
        artifacts_dir: artifacts,
    })?;
    for a in 0..3u32 {
        server.register_adapter(AdapterId(a));
    }

    // A task-skewed request mix: consecutive same-task requests hit the
    // resident adapter; task switches pay an SRPG reprogramming pass.
    let mut rng = Rng::new(42);
    let mut reqs = Vec::new();
    let mut task = 0u32;
    for i in 0..12u64 {
        if rng.f64() < 0.4 {
            task = rng.range(0, 3) as u32;
        }
        reqs.push(Request {
            id: i,
            adapter: AdapterId(task),
            input_tokens: 256 + rng.range(0, 256),
            output_tokens: 64,
        });
    }
    for r in reqs {
        server.submit(r)?;
    }

    let (tx, rx) = mpsc::channel();
    let results = server.run(Some(&tx))?;
    drop(tx);
    let tokens: Vec<_> = rx.iter().collect();

    println!("  req  task  swap  ttft_s  itl_ms  golden_ms");
    for r in &results {
        println!(
            "  {:>3}  {:>4}  {:>4}  {:>6.3}  {:>6.3}  {:>8.1}",
            r.request,
            r.adapter.0,
            if r.swap { "yes" } else { "-" },
            r.ttft_s,
            r.itl_ms,
            r.golden_exec_ms.unwrap_or(0.0),
        );
    }
    let s = server.stats();
    println!(
        "\n  served {} requests / {} tokens in {:.2} simulated s \
         ({:.1} tok/s sustained)",
        s.served,
        s.total_tokens,
        s.sim_time_s,
        s.total_tokens as f64 / s.sim_time_s,
    );
    println!(
        "  adapter swaps {}, hits {} — hits skip reprogramming entirely",
        s.adapter_swaps, s.adapter_hits
    );
    println!("  token stream: {} events, monotone per request", tokens.len());

    // Sanity: the stream carried every generated token.
    let expect: usize = results.iter().map(|r| r.tokens_out).sum();
    assert_eq!(tokens.len(), expect);
    println!("\nE2E OK — all layers composed (PJRT numerics + coordinator + simulator)");
    Ok(())
}
