//! Quickstart: simulate one PRIMAL benchmark point and print the report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the five-line introduction to the public API: build an
//! [`ExperimentConfig`] for one of the paper's benchmark points, run the
//! cycle-accurate simulator, read the Table II/III quantities off the
//! report.

use primal::config::{ExperimentConfig, LoraTarget, ModelId};
use primal::sim::Simulator;

fn main() {
    // The paper's headline point: Llama-13B, 2048/2048, LoRA rank 8 (Q,V).
    let cfg = ExperimentConfig::paper_point(
        ModelId::Llama2_13b,
        &[LoraTarget::Q, LoraTarget::V],
        2048,
    );

    let report = Simulator::new(&cfg).run();

    println!("PRIMAL quickstart — {}", report.model);
    println!("  CT allocation : {} CTs ({} per layer, layer-wise adjacent)",
             report.total_cts, report.cts_per_layer);
    println!("  TTFT          : {:.3} s   (paper: 2.533 s)", report.ttft_s);
    println!("  ITL           : {:.3} ms  (paper: 12.518 ms)", report.itl_ms);
    println!("  throughput    : {:.2} tok/s (paper: 145.40)", report.throughput_tps);
    println!("  avg power     : {:.2} W    (paper: 17.70)", report.avg_power_w);
    println!("  efficiency    : {:.2} tok/J (paper: 9.85)", report.efficiency_tpj);

    // The same API drives ablations: switch SRPG off and re-run.
    let mut no_srpg = cfg.clone();
    no_srpg.srpg = false;
    let baseline = Simulator::new(&no_srpg).run();
    println!(
        "  SRPG saving   : {:.1}% power ({:.2} W -> {:.2} W)",
        100.0 * (1.0 - report.avg_power_w / baseline.avg_power_w),
        baseline.avg_power_w,
        report.avg_power_w,
    );
}
