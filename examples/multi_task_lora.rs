//! Multi-task LoRA adaptation: the scenario PRIMAL's SRPG was built for.
//!
//! ```bash
//! cargo run --release --example multi_task_lora
//! ```
//!
//! A deployment serves N downstream tasks from one base model; every task
//! switch must reprogram the SRAM-DCIM macros with that task's LoRA
//! matrices. This example quantifies what SRPG buys:
//!
//!  * task-switch TTFT with SRPG (reprogram first CT group, hide the
//!    rest behind compute) vs without (all groups up front);
//!  * the power cost of keeping idle CT groups ungated (no power gating)
//!    vs SRPG's retention-only gating;
//!  * how switch frequency in the request mix changes effective
//!    throughput for both configurations.

use primal::config::{ExperimentConfig, LoraTarget, ModelId};
use primal::coordinator::{AdapterId, FunctionalMode, Request, Server, ServerConfig};
use primal::sim::Simulator;
use primal::util::Rng;

fn serve_mix(srpg: bool, switch_prob: f64, n_requests: usize) -> (f64, f64) {
    let mut cfg = ExperimentConfig::paper_point(
        ModelId::Llama3_8b,
        &[LoraTarget::Q, LoraTarget::V],
        512,
    );
    cfg.srpg = srpg;
    let mut server = Server::new(ServerConfig {
        experiment: cfg,
        functional: FunctionalMode::TimingOnly,
        artifacts_dir: "artifacts".into(),
    })
    .expect("server");
    for a in 0..4u32 {
        server.register_adapter(AdapterId(a));
    }
    let mut rng = Rng::new(99);
    let mut task = 0u32;
    for i in 0..n_requests as u64 {
        if rng.f64() < switch_prob {
            task = rng.range(0, 4) as u32;
        }
        server
            .submit(Request::new(i, AdapterId(task), 512, 64))
            .unwrap();
    }
    server.run(None).unwrap();
    let s = server.stats();
    (
        s.total_tokens as f64 / s.sim_time_s, // sustained tok/s
        s.mean_ttft_s,
    )
}

fn main() {
    println!("PRIMAL multi-task LoRA serving — Llama 3 8B, 4 downstream tasks\n");

    // ---- single-switch latency anatomy ---------------------------------
    for srpg in [true, false] {
        let mut cfg = ExperimentConfig::paper_point(
            ModelId::Llama3_8b,
            &[LoraTarget::Q, LoraTarget::V],
            512,
        );
        cfg.srpg = srpg;
        let r = Simulator::new(&cfg).run();
        println!(
            "  SRPG {:>3}: cold-task TTFT {:.3} s, avg power {:.2} W ({} CTs)",
            if srpg { "on" } else { "off" },
            r.ttft_s,
            r.avg_power_w,
            r.total_cts
        );
    }

    // ---- request-mix sweep ----------------------------------------------
    println!("\n  switch-prob   SRPG tok/s   no-SRPG tok/s   SRPG mean-TTFT");
    for p in [0.0, 0.25, 0.5, 1.0] {
        let (tput_on, ttft_on) = serve_mix(true, p, 16);
        let (tput_off, _) = serve_mix(false, p, 16);
        println!(
            "  {:>10.2}   {:>10.1}   {:>13.1}   {:>13.3}s",
            p, tput_on, tput_off, ttft_on
        );
    }

    println!(
        "\nSRPG keeps task-switch cost at one CT group's reprogramming and \
         gates idle groups; the no-SRPG baseline pays the full model's \
         reprogramming on every switch and full idle power throughout."
    );
}
