//! Regenerate the paper's Fig. 6: the hardware-scheduling timing diagram
//! for Llama 3.2-1B on PRIMAL.
//!
//! ```bash
//! cargo run --release --example timing_diagram
//! ```
//!
//! Shows the SRPG pipeline: CT group 0's SRAMs reprogram first (the only
//! reprogramming on the TTFT critical path), subsequent groups reprogram
//! while earlier groups compute, prefill sweeps the groups layer by
//! layer, and decode then walks the same chain per token while idle
//! groups sit power-gated ('.').

use primal::config::{ExperimentConfig, LoraTarget, ModelId};
use primal::sim::Simulator;
use primal::trace::{kind_totals, render_gantt};

fn main() {
    // A short context keeps the diagram legible (the structure is the
    // same at the paper's 1024/1024 point).
    let cfg = ExperimentConfig::paper_point(
        ModelId::Llama32_1b,
        &[LoraTarget::Q, LoraTarget::V],
        256,
    );
    let report = Simulator::new(&cfg).with_trace().run();

    println!("Fig. 6 — hardware scheduling, {} (256/256, LoRA r8 Q,V)\n", report.model);
    println!("{}", render_gantt(&report.trace, 110));

    println!("per-activity busy cycles:");
    for (k, v) in kind_totals(&report.trace) {
        println!("  {k:<16} {v:>12}");
    }
    println!(
        "\nreprogramming pipeline stalls: {} cycles (0 = fully hidden \
         behind compute, as the paper claims for TTFT)",
        report.reprog_stall_cycles
    );
    println!(
        "TTFT {:.3} s = CT0 reprogram + layer-sequential prefill; ITL {:.3} ms",
        report.ttft_s, report.itl_ms
    );
}
