"""Pallas kernel for the PRIMAL PE-pair hot spot: crossbar SMAC + LoRA.

One grid step of this kernel emulates one Router-PE pair of the IPCN:

  * a 256x256 int8 RRAM-ACIM tile performs the static-weight MAC over a
    DAC-quantized activation slice (analog bit-line accumulation ->
    expressed as an MXU-shaped int8 x int8 -> int32 matmul),
  * the attached 256x64 SRAM-DCIM macro contributes the digital LoRA
    partial product for the same activation slice,
  * the IPCN reduction over K-tiles is expressed as a grid-carried
    accumulation into the output block (revisited across the K grid
    dimension), mirroring the in-network partial-sum reduction tree.

TPU mapping notes (DESIGN.md SS Hardware-Adaptation): the crossbar tile is
one BlockSpec block pinned in VMEM across the K-grid sweep
(weight-stationary, exactly the RRAM "program once" property); the DAC /
ADC quantization is elementwise VPU work; the 256x256 int8 MAC is
MXU-native. Kernels are lowered with `interpret=True` -- real-TPU Mosaic
lowering cannot execute on the CPU PJRT plugin (see /opt/xla-example).

Grid: (M/TILE_M, K/TILE_K); output block [T, TILE_M] is revisited for
every k-step, so the kernel initializes it at k==0 and accumulates after.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INT8_QMAX, RRAM_TILE_COLS, RRAM_TILE_ROWS

TILE_M = RRAM_TILE_ROWS
TILE_K = RRAM_TILE_COLS


def _pe_pair_kernel(x_ref, wq_ref, wscale_ref, a_ref, b_ref, o_ref, ab_ref):
    """One Router-PE pair step: quantize slice, crossbar MAC, LoRA MAC.

    Block shapes:
      x_ref:      [T, TILE_K]  activation slice for this K-tile
      wq_ref:     [TILE_M, TILE_K] int8 crossbar tile
      wscale_ref: [1, 1]      per-tile weight scale
      a_ref:      [R, TILE_K] LoRA A slice (digital SRAM-DCIM rows)
      b_ref:      [TILE_M, R] LoRA B tile
      o_ref:      [T, TILE_M] output block (revisited across k)
      ab_ref:     [T, R]      scratch-like carried x@A^T partial (revisited)
    """
    kt = pl.program_id(1)
    n_kt = pl.num_programs(1)

    x = x_ref[...]

    # --- DAC: symmetric int8 quantization of the activation slice -------
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    x_scale = jnp.where(absmax > 0, absmax, 1.0) / INT8_QMAX
    xq = jnp.clip(jnp.round(x / x_scale), -INT8_QMAX, INT8_QMAX)
    xq = xq.astype(jnp.int8)

    # --- RRAM-ACIM: int8 x int8 -> int32 bit-line accumulation ----------
    # (MXU-shaped matmul; accumulate in int32 like the analog read-out.)
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32),
        wq_ref[...].astype(jnp.int32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # [T, TILE_M]

    # --- ADC read-out: dequantize this tile's partial sum ---------------
    partial = acc.astype(jnp.float32) * x_scale * wscale_ref[0, 0]

    # --- SRAM-DCIM: digital LoRA partial (x_slice @ A_slice^T) ----------
    ab_partial = jax.lax.dot_general(
        x, a_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [T, R]

    # --- IPCN reduction: accumulate across the K grid dimension ---------
    @pl.when(kt == 0)
    def _init():
        o_ref[...] = partial
        ab_ref[...] = ab_partial

    @pl.when(kt > 0)
    def _accum():
        o_ref[...] += partial
        ab_ref[...] += ab_partial

    # --- Final k-step: apply LoRA B (second SRAM-DCIM stage) ------------
    @pl.when(kt == n_kt - 1)
    def _finish():
        o_ref[...] += jax.lax.dot_general(
            ab_ref[...], b_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def pim_lora_matmul(x, wq, w_scales, a, b, *, interpret: bool = True):
    """PRIMAL PE-array matmul: y = dequant(xq @ Wq^T) + (x @ A^T) @ B^T.

    x:        [T, K] f32     activations (T tokens / sequence block)
    wq:       [M, K] int8    crossbar conductances (from quantize_weight_tiles)
    w_scales: [M/256, K/256] f32 per-tile scales
    a:        [R, K] f32     LoRA A (R <= 64, one SRAM-DCIM column bank)
    b:        [M, R] f32     LoRA B
    Returns   [T, M] f32.
    """
    t, k = x.shape
    m = wq.shape[0]
    r = a.shape[0]
    assert m % TILE_M == 0 and k % TILE_K == 0, (m, k)
    assert b.shape == (m, r) and a.shape == (r, k)
    n_mt, n_kt = m // TILE_M, k // TILE_K

    grid = (n_mt, n_kt)
    out, _ = pl.pallas_call(
        _pe_pair_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, TILE_K), lambda i, j: (0, j)),          # x
            pl.BlockSpec((TILE_M, TILE_K), lambda i, j: (i, j)),     # wq
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),               # scales
            pl.BlockSpec((r, TILE_K), lambda i, j: (0, j)),          # A
            pl.BlockSpec((TILE_M, r), lambda i, j: (i, 0)),          # B
        ],
        out_specs=[
            pl.BlockSpec((t, TILE_M), lambda i, j: (0, i)),          # y
            pl.BlockSpec((t, r), lambda i, j: (0, 0)),               # x@A^T carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, m), jnp.float32),
            jax.ShapeDtypeStruct((t, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, wq, w_scales, a, b)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def pim_matmul(x, wq, w_scales, *, interpret: bool = True):
    """Crossbar-only SMAC (no LoRA path) -- used for K and MLP projections."""
    t, k = x.shape
    m = wq.shape[0]
    # Zero-rank LoRA degenerates numerically; reuse the fused kernel with
    # rank-1 zeros to keep a single code path on hardware and in tests.
    a = jnp.zeros((1, k), jnp.float32)
    b = jnp.zeros((m, 1), jnp.float32)
    return pim_lora_matmul(x, wq, w_scales, a, b, interpret=interpret)
