"""PRIMAL L1 Pallas kernels + pure-jnp oracles.

`lora_matmul`   -- PE-pair crossbar SMAC with fused SRAM-DCIM LoRA path.
`attention`     -- router-executed DMAC attention over scratchpad KV blocks.
`ref`           -- the numerical contract both kernels and the Rust
                   fixed-point model must satisfy.
"""

from . import attention, lora_matmul, ref  # noqa: F401
