"""Pure-jnp oracles for the PRIMAL L1 kernels.

These functions define the *numerical contract* of the PRIMAL compute
fabric. Three implementations must agree with them:

  1. the Pallas kernels in `lora_matmul.py` / `attention.py` (pytest,
     this package's `tests/`),
  2. the lowered HLO artifacts executed by the Rust runtime
     (`rust/src/runtime/` integration tests),
  3. the Rust fixed-point PE model (`rust/src/pe/numerics.rs`), which
     re-implements the same quantization spec in integer arithmetic.

Quantization spec (mirrors the RRAM-ACIM macro of Wan et al. [5] at the
behavioural level):

  * Pre-trained weights live in the analog crossbar as **int8** conductances
    with one float scale per 256x256 tile:
        scale_w[i,j] = max(|W_tile|) / 127 ,  Wq = round(W / scale_w)
  * Activations are converted by the DAC per 256-element K-slice:
        scale_x[j]   = max(|x_slice|) / 127 ,  xq = round(x / scale_x)
    (clipped to [-127, 127]; the symmetric range avoids -128 asymmetry,
    matching typical ACIM DAC designs).
  * The bit-line accumulation is exact in int32 (256 * 127 * 127 < 2^31),
    then the ADC read-out re-scales: partial = acc * scale_w * scale_x.
    An optional `adc_bits` models a finite-resolution ADC by uniformly
    quantizing each tile's partial sum into 2^adc_bits levels over its
    full-scale range.
  * The LoRA path runs on the **digital** SRAM-DCIM macro and is computed
    in float32 ("highly accurate digital MAC" -- paper SS II.A.2).

All tensors are float32 unless stated otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Tile geometry fixed by the macros (paper Table I).
RRAM_TILE_ROWS = 256  # crossbar output (column) dimension per tile
RRAM_TILE_COLS = 256  # crossbar input (row) dimension per tile
SRAM_TILE_ROWS = 256
SRAM_TILE_COLS = 64  # => max LoRA rank handled by one SRAM-DCIM macro

INT8_QMAX = 127.0


# --------------------------------------------------------------------------
# Quantization helpers
# --------------------------------------------------------------------------

def symmetric_scale(t: jnp.ndarray, axis=None) -> jnp.ndarray:
    """Symmetric int8 scale max(|t|)/127, guarded against all-zero inputs."""
    m = jnp.max(jnp.abs(t), axis=axis, keepdims=axis is not None)
    return jnp.where(m > 0, m, 1.0) / INT8_QMAX


def quantize_i8(t: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest symmetric int8 quantization (returns int8)."""
    q = jnp.round(t / scale)
    return jnp.clip(q, -INT8_QMAX, INT8_QMAX).astype(jnp.int8)


def quantize_weight_tiles(w: jnp.ndarray):
    """Quantize a [M, K] weight matrix into 256x256 int8 crossbar tiles.

    Returns (wq int8 [M, K], scales f32 [M/256, K/256]). M and K must be
    multiples of the tile size -- the mapping layer pads to tile boundaries
    before programming the crossbars, exactly as the hardware leaves
    unused rows/columns unprogrammed.
    """
    m, k = w.shape
    tm, tk = RRAM_TILE_ROWS, RRAM_TILE_COLS
    assert m % tm == 0 and k % tk == 0, f"untiled shape {w.shape}"
    tiles = w.reshape(m // tm, tm, k // tk, tk)
    scales = jnp.max(jnp.abs(tiles), axis=(1, 3))
    scales = jnp.where(scales > 0, scales, 1.0) / INT8_QMAX
    wq = jnp.round(tiles / scales[:, None, :, None])
    wq = jnp.clip(wq, -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return wq.reshape(m, k), scales


# --------------------------------------------------------------------------
# SMAC: static-weight MAC on the RRAM-ACIM crossbar (+ fused LoRA path)
# --------------------------------------------------------------------------

def pim_matmul_ref(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    w_scales: jnp.ndarray,
    adc_bits: int | None = None,
) -> jnp.ndarray:
    """Reference for the quantized crossbar matmul  y = dequant(xq @ Wq^T).

    x:        [T, K] float32 activations (T tokens).
    wq:       [M, K] int8 crossbar conductances (tiled quantization).
    w_scales: [M/256, K/256] float32 per-tile scales.
    Returns   [T, M] float32.

    Computation proceeds tile-by-tile exactly as the hardware does:
    per K-slice DAC quantization of x, int32 bit-line accumulation within
    each 256x256 tile, ADC read-out, then the IPCN reduction over K tiles.
    """
    t, k = x.shape
    m = wq.shape[0]
    tm, tk = RRAM_TILE_ROWS, RRAM_TILE_COLS
    n_mt, n_kt = m // tm, k // tk

    # DAC: per-(token, K-slice) activation quantization.
    xs = x.reshape(t, n_kt, tk)
    x_scale = symmetric_scale(xs, axis=2)  # [T, n_kt, 1]
    xq = jnp.round(xs / x_scale)
    xq = jnp.clip(xq, -INT8_QMAX, INT8_QMAX).astype(jnp.int8)

    wt = wq.reshape(n_mt, tm, n_kt, tk)

    # int32 bit-line accumulate per tile: [T, n_kt, n_mt, tm]
    acc = jnp.einsum(
        "tkc,mrkc->tkmr",
        xq.astype(jnp.int32),
        wt.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    # ADC read-out: rescale per tile.
    partial = (
        acc.astype(jnp.float32)
        * x_scale[:, :, :, None]          # [T, n_kt, 1, 1]
        * w_scales.T[None, :, :, None]    # [1, n_kt, n_mt, 1]
    )
    if adc_bits is not None:
        # Finite-resolution ADC: uniform quantization of each tile's
        # partial sum over the tile's full-scale range.
        full_scale = (
            INT8_QMAX * INT8_QMAX * tk
            * x_scale[:, :, :, None]
            * w_scales.T[None, :, :, None]
        )
        lsb = 2.0 * full_scale / (2.0 ** adc_bits)
        partial = jnp.round(partial / lsb) * lsb
    # IPCN reduction over K tiles.
    return partial.sum(axis=1).reshape(t, m)


def lora_path_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Digital SRAM-DCIM LoRA path:  y = (x @ A^T) @ B^T  in float32.

    x: [T, K], a: [r, K], b: [M, r]  ->  [T, M].
    """
    return (x @ a.T) @ b.T


def pim_lora_matmul_ref(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    w_scales: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    adc_bits: int | None = None,
) -> jnp.ndarray:
    """Full PE-pair computation: crossbar SMAC + fused digital LoRA path."""
    return pim_matmul_ref(x, wq, w_scales, adc_bits) + lora_path_ref(x, a, b)


# --------------------------------------------------------------------------
# DMAC: dynamic MAC attention executed in the IPCN routers
# --------------------------------------------------------------------------

def dmac_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_len: jnp.ndarray | int | None = None,
) -> jnp.ndarray:
    """Reference for router-executed attention (decode: one query token).

    q: [H, D], k/v: [S, H, D] (scratchpad KV cache, S = allocated capacity).
    kv_len: number of valid cache rows (<= S); the rest are masked.
    None => all S rows valid. Returns [H, D]. float32 throughout -- the
    DMAC units are digital full-precision MACs inside the routers
    (paper SS II.B).
    """
    s, h, d = k.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))
    scores = jnp.einsum("hd,shd->hs", q, k) * scale
    if kv_len is not None:
        mask = jnp.arange(s)[None, :] < kv_len
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hs,shd->hd", p, v)


def dmac_attention_prefill_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray
) -> jnp.ndarray:
    """Causal prefill attention. q/k/v: [T, H, D] -> [T, H, D]."""
    t, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))
    scores = jnp.einsum("thd,shd->hts", q, k) * scale
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,shd->thd", p, v)
