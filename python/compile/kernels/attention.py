"""Pallas kernel for PRIMAL's in-network DMAC attention.

In PRIMAL the attention score Q.K^T, softmax and the A.V product are
executed by the DMAC units *inside the IPCN routers*, streaming over KV
tiles held in the distributed scratchpads (cyclic placement, paper
SS III.B). The natural TPU expression is an online-softmax (flash-style)
kernel that sweeps 256-row KV blocks -- each block corresponds to one
scratchpad region / router neighbourhood, and the running (m, l, acc)
re-normalization corresponds to the in-network reduction of partial
attention results.

Decode only (single query token): the prefill path uses the same kernel
per query block inside model.py. Lowered with interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KV_BLOCK = 256  # scratchpad KV block: 256 rows, matching the macro tiling

_NEG_INF = -1e30


def _dmac_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref):
    """Online-softmax decode attention over one KV block for all heads.

    Block shapes (H = heads, D = head_dim, B = KV_BLOCK):
      q_ref:   [H, D]      query token
      k_ref:   [B, H, D]   KV-cache key block (one scratchpad region)
      v_ref:   [B, H, D]   value block
      len_ref: [1, 1]      valid KV length (int32)
      o_ref:   [H, D]      output (written on final block)
      m/l/acc: carried softmax state, revisited on every block
    """
    blk = pl.program_id(0)
    n_blk = pl.num_programs(0)

    q = q_ref[...]                    # [H, D]
    k = k_ref[...]                    # [B, H, D]
    v = v_ref[...]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    # Router DMAC: scores for this block.  [H, B]
    s = jnp.einsum("hd,bhd->hb", q, k) * scale

    # Mask rows beyond the live KV length.
    kv_len = len_ref[0, 0]
    row = blk * KV_BLOCK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(row < kv_len, s, _NEG_INF)

    m_blk = jnp.max(s, axis=1, keepdims=True)             # [H, 1]
    p = jnp.exp(s - m_blk)                                # [H, B]
    # Fully-masked block guard (kv_len may end before this block).
    p = jnp.where(m_blk > _NEG_INF / 2, p, 0.0)
    l_blk = jnp.sum(p, axis=1, keepdims=True)             # [H, 1]
    pv = jnp.einsum("hb,bhd->hd", p, v)                   # [H, D]

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = m_blk
        l_ref[...] = l_blk
        acc_ref[...] = pv

    @pl.when(blk > 0)
    def _merge():
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, m_blk)
        alpha = jnp.exp(m_prev - m_new)   # rescale old state
        beta = jnp.exp(m_blk - m_new)     # rescale this block
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + l_blk * beta
        acc_ref[...] = acc_ref[...] * alpha + pv * beta

    @pl.when(blk == n_blk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[...] = acc_ref[...] / jnp.where(l > 0, l, 1.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dmac_attention(q, k, v, kv_len, *, interpret: bool = True):
    """Decode attention over the scratchpad KV cache.

    q: [H, D] f32; k/v: [S, H, D] f32 with S a multiple of KV_BLOCK;
    kv_len: scalar int32, number of valid rows. Returns [H, D] f32.
    """
    h, d = q.shape
    s = k.shape[0]
    assert s % KV_BLOCK == 0, s
    n_blk = s // KV_BLOCK
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1, 1)

    out, _, _, _ = pl.pallas_call(
        _dmac_decode_kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((h, d), lambda b: (0, 0)),                 # q
            pl.BlockSpec((KV_BLOCK, h, d), lambda b: (b, 0, 0)),    # k
            pl.BlockSpec((KV_BLOCK, h, d), lambda b: (b, 0, 0)),    # v
            pl.BlockSpec((1, 1), lambda b: (0, 0)),                 # kv_len
        ],
        out_specs=[
            pl.BlockSpec((h, d), lambda b: (0, 0)),                 # out
            pl.BlockSpec((h, 1), lambda b: (0, 0)),                 # m
            pl.BlockSpec((h, 1), lambda b: (0, 0)),                 # l
            pl.BlockSpec((h, d), lambda b: (0, 0)),                 # acc
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, d), jnp.float32),
            jax.ShapeDtypeStruct((h, 1), jnp.float32),
            jax.ShapeDtypeStruct((h, 1), jnp.float32),
            jax.ShapeDtypeStruct((h, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
    return out
