"""L2: PRIMAL's compute graph -- a LoRA-augmented Llama-style decoder layer.

This is the JAX expression of exactly what the PRIMAL fabric computes for
one transformer layer (paper Fig. 4 / SS III): RMSNorm -> Q/K/V projections
on the RRAM crossbars with the SRAM-DCIM LoRA path fused on the adapted
matrices -> RoPE -> in-network DMAC attention over the scratchpad KV cache
-> O projection -> SwiGLU MLP (also crossbar SMAC).

Everything is built from the L1 kernels so that lowering produces a single
HLO module per entry point; `aot.py` dumps these as HLO text for the Rust
runtime (`rust/src/runtime/`), which executes them on the request path for
functional (golden-model) validation of the cycle simulator's fixed-point
numerics. Python itself never runs at serving time.

Weights are carried pre-quantized (int8 tiles + per-tile scales), i.e. in
the exact form the mapping layer programs into the crossbars.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import dmac_attention
from .kernels.lora_matmul import pim_lora_matmul, pim_matmul


@dataclasses.dataclass(frozen=True)
class LayerConfig:
    """Shape configuration for one decoder layer.

    All projection dims must be multiples of the 256 crossbar tile; the
    mapping layer pads real models to tile boundaries, so the AOT shapes
    are already tile-aligned.
    """

    hidden: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    intermediate: int = 1024
    lora_rank: int = 8
    lora_targets: tuple[str, ...] = ("q", "v")  # which of q,k,v,o are adapted
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    kv_capacity: int = 512  # scratchpad KV allocation (multiple of 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


class QuantLinear(NamedTuple):
    """A crossbar-programmed projection: int8 tiles + per-tile scales."""

    wq: jnp.ndarray       # [M, K] int8
    scales: jnp.ndarray   # [M/256, K/256] f32


class LoraPair(NamedTuple):
    """A LoRA adapter held in SRAM-DCIM: y += (x @ A^T) @ B^T."""

    a: jnp.ndarray  # [r, K] f32
    b: jnp.ndarray  # [M, r] f32


class LayerWeights(NamedTuple):
    """All weights of one decoder layer in programmed (on-chip) form."""

    attn_norm: jnp.ndarray   # [hidden]
    mlp_norm: jnp.ndarray    # [hidden]
    wq: QuantLinear
    wk: QuantLinear
    wv: QuantLinear
    wo: QuantLinear
    w_gate: QuantLinear
    w_up: QuantLinear
    w_down: QuantLinear
    lora_q: LoraPair
    lora_k: LoraPair
    lora_v: LoraPair
    lora_o: LoraPair


def _zero_lora(m: int, k: int) -> LoraPair:
    """Rank-1 zero adapter: numerically inert, keeps one kernel code path."""
    return LoraPair(jnp.zeros((1, k), jnp.float32), jnp.zeros((m, 1), jnp.float32))


def init_layer_weights(cfg: LayerConfig, key: jax.Array) -> LayerWeights:
    """Random synthetic weights in programmed form (timing is shape-only;
    numerics are validated on this reduced model -- DESIGN.md substitutions)."""
    ks = jax.random.split(key, 12)
    h, qd, kvd, im = cfg.hidden, cfg.q_dim, cfg.kv_dim, cfg.intermediate

    def q(key, m, k, std):
        w = jax.random.normal(key, (m, k), jnp.float32) * std
        return QuantLinear(*ref.quantize_weight_tiles(w))

    def lora(key, name, m, k):
        if name not in cfg.lora_targets:
            return _zero_lora(m, k)
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, (cfg.lora_rank, k), jnp.float32) * (1.0 / k**0.5)
        # Standard LoRA init sets B = 0; use a small non-zero B so tests
        # actually exercise the SRAM-DCIM path.
        b = jax.random.normal(kb, (m, cfg.lora_rank), jnp.float32) * 0.02
        return LoraPair(a, b)

    std = 1.0 / h**0.5
    return LayerWeights(
        attn_norm=jnp.ones((h,), jnp.float32),
        mlp_norm=jnp.ones((h,), jnp.float32),
        wq=q(ks[0], qd, h, std),
        wk=q(ks[1], kvd, h, std),
        wv=q(ks[2], kvd, h, std),
        wo=q(ks[3], h, qd, std),
        w_gate=q(ks[4], im, h, std),
        w_up=q(ks[5], im, h, std),
        w_down=q(ks[6], h, im, 1.0 / im**0.5),
        lora_q=lora(ks[7], "q", qd, h),
        lora_k=lora(ks[8], "k", kvd, h),
        lora_v=lora(ks[9], "v", kvd, h),
        lora_o=lora(ks[10], "o", h, qd),
    )


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float):
    """cos/sin tables for the given absolute positions. [T, head_dim/2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [T, H, D]; cos/sin: [T, D/2] (split-half convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos[:, None, :], sin[:, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _proj(x, lin: QuantLinear, lora: LoraPair, interpret: bool) -> jnp.ndarray:
    return pim_lora_matmul(x, lin.wq, lin.scales, lora.a, lora.b,
                           interpret=interpret)


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """GQA: expand [*, n_kv, D] -> [*, n_kv*groups, D]."""
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=-2)


# --------------------------------------------------------------------------
# Entry points (the units aot.py lowers)
# --------------------------------------------------------------------------

def decode_step(
    cfg: LayerConfig,
    w: LayerWeights,
    x: jnp.ndarray,          # [hidden] current token's hidden state
    k_cache: jnp.ndarray,    # [S, n_kv, D] scratchpad K blocks
    v_cache: jnp.ndarray,    # [S, n_kv, D]
    pos: jnp.ndarray,        # scalar int32: this token's position
    *,
    interpret: bool = True,
):
    """One decoder-layer decode step. Returns (y [hidden], k_new, v_new).

    The caller (Rust coordinator) owns the cache append -- mirroring the
    hardware, where the router writes the fresh K/V rows into the cyclic
    scratchpad buffer (dataflow SS III.B) and the DMAC units then read
    capacity-S blocks with a validity length.
    """
    h = rms_norm(x[None, :], w.attn_norm, cfg.rms_eps)  # [1, hidden]

    q = _proj(h, w.wq, w.lora_q, interpret).reshape(1, cfg.n_heads, cfg.head_dim)
    k = _proj(h, w.wk, w.lora_k, interpret).reshape(1, cfg.n_kv_heads, cfg.head_dim)
    v = _proj(h, w.wv, w.lora_v, interpret).reshape(1, cfg.n_kv_heads, cfg.head_dim)

    cos, sin = rope_tables(pos[None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)[0]       # [H, D]
    k_new = apply_rope(k, cos, sin)[0]   # [n_kv, D]
    v_new = v[0]

    # Append this token's K/V at index `pos` (functional update; the Rust
    # side does the same append into the cyclic scratchpad region).
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new[None], (pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new[None], (pos, 0, 0))

    groups = cfg.n_heads // cfg.n_kv_heads
    k_full = _repeat_kv(k_cache, groups)
    v_full = _repeat_kv(v_cache, groups)
    attn = dmac_attention(q, k_full, v_full, pos + 1, interpret=interpret)

    o = _proj(attn.reshape(1, cfg.q_dim), w.wo, w.lora_o, interpret)[0]
    x = x + o

    # SwiGLU MLP on the crossbars.
    hm = rms_norm(x[None, :], w.mlp_norm, cfg.rms_eps)
    gate = pim_matmul(hm, w.w_gate.wq, w.w_gate.scales, interpret=interpret)
    up = pim_matmul(hm, w.w_up.wq, w.w_up.scales, interpret=interpret)
    act = jax.nn.silu(gate) * up
    down = pim_matmul(act, w.w_down.wq, w.w_down.scales, interpret=interpret)
    return x + down[0], k_new, v_new


def prefill_block(
    cfg: LayerConfig,
    w: LayerWeights,
    x: jnp.ndarray,    # [T, hidden] block of prompt hidden states
    pos0: jnp.ndarray, # scalar int32: absolute position of x[0]
    *,
    interpret: bool = True,
):
    """Prefill one decoder layer over a T-token block (causal within block).

    Returns (y [T, hidden], k_block [T, n_kv, D], v_block [T, n_kv, D]);
    the K/V block is handed to the coordinator for scratchpad placement.
    Block-local causal attention matches PRIMAL's per-CT prefill pipeline
    (Fig. 6): each CT computes attention over the tokens resident in its
    scratchpads.
    """
    t = x.shape[0]
    h = rms_norm(x, w.attn_norm, cfg.rms_eps)

    q = _proj(h, w.wq, w.lora_q, interpret).reshape(t, cfg.n_heads, cfg.head_dim)
    k = _proj(h, w.wk, w.lora_k, interpret).reshape(t, cfg.n_kv_heads, cfg.head_dim)
    v = _proj(h, w.wv, w.lora_v, interpret).reshape(t, cfg.n_kv_heads, cfg.head_dim)

    positions = pos0 + jnp.arange(t)
    cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    groups = cfg.n_heads // cfg.n_kv_heads
    attn = ref.dmac_attention_prefill_ref(
        q, _repeat_kv(k, groups), _repeat_kv(v, groups)
    )

    o = _proj(attn.reshape(t, cfg.q_dim), w.wo, w.lora_o, interpret)
    x = x + o

    hm = rms_norm(x, w.mlp_norm, cfg.rms_eps)
    gate = pim_matmul(hm, w.w_gate.wq, w.w_gate.scales, interpret=interpret)
    up = pim_matmul(hm, w.w_up.wq, w.w_up.scales, interpret=interpret)
    act = jax.nn.silu(gate) * up
    down = pim_matmul(act, w.w_down.wq, w.w_down.scales, interpret=interpret)
    return x + down, k, v


def decode_step_ref(cfg: LayerConfig, w: LayerWeights, x, k_cache, v_cache, pos):
    """Pure-jnp oracle for decode_step (uses ref kernels throughout)."""
    h = rms_norm(x[None, :], w.attn_norm, cfg.rms_eps)

    def proj(lin, lora):
        return ref.pim_lora_matmul_ref(h, lin.wq, lin.scales, lora.a, lora.b)

    q = proj(w.wq, w.lora_q).reshape(1, cfg.n_heads, cfg.head_dim)
    k = proj(w.wk, w.lora_k).reshape(1, cfg.n_kv_heads, cfg.head_dim)
    v = proj(w.wv, w.lora_v).reshape(1, cfg.n_kv_heads, cfg.head_dim)
    cos, sin = rope_tables(pos[None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)[0]
    k_new = apply_rope(k, cos, sin)[0]
    v_new = v[0]
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new[None], (pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new[None], (pos, 0, 0))
    groups = cfg.n_heads // cfg.n_kv_heads
    attn = ref.dmac_attention_ref(
        q, _repeat_kv(k_cache, groups), _repeat_kv(v_cache, groups), pos + 1
    )
    ah = attn.reshape(1, cfg.q_dim)
    o = ref.pim_lora_matmul_ref(ah, w.wo.wq, w.wo.scales, w.lora_o.a, w.lora_o.b)
    x = x + o[0]
    hm = rms_norm(x[None, :], w.mlp_norm, cfg.rms_eps)
    gate = ref.pim_matmul_ref(hm, w.w_gate.wq, w.w_gate.scales)
    up = ref.pim_matmul_ref(hm, w.w_up.wq, w.w_up.scales)
    act = jax.nn.silu(gate) * up
    down = ref.pim_matmul_ref(act, w.w_down.wq, w.w_down.scales)
    return x + down[0], k_new, v_new


@functools.lru_cache(maxsize=None)
def jitted_decode_step(cfg: LayerConfig, interpret: bool = True):
    """jax.jit'ed decode_step closed over cfg (weights as tracers)."""
    def f(w, x, k_cache, v_cache, pos):
        return decode_step(cfg, w, x, k_cache, v_cache, pos, interpret=interpret)
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def jitted_prefill_block(cfg: LayerConfig, interpret: bool = True):
    def f(w, x, pos0):
        return prefill_block(cfg, w, x, pos0, interpret=interpret)
    return jax.jit(f)
