"""AOT lowering: JAX entry points -> HLO text + weight/golden manifests.

Run once by `make artifacts`; never on the request path. Emits, per entry
point, an HLO **text** module (xla_extension 0.5.1 rejects jax>=0.5
serialized HloModuleProto -- 64-bit instruction ids; the text parser
reassigns ids, see /opt/xla-example/README.md), plus:

  artifacts/manifest.json      -- parameter order/shapes/dtypes per module,
                                  model config, golden-vector descriptors
  artifacts/data/<name>.bin    -- little-endian raw tensors (weights,
                                  golden inputs, golden outputs)

The Rust runtime (`rust/src/runtime/`) loads the HLO text via
`HloModuleProto::from_text_file`, feeds the parameters in manifest order,
and checks the golden outputs -- this is the L2<->L3 functional bridge.

Usage: python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref
from .kernels.lora_matmul import pim_lora_matmul

# Reduced-but-tile-aligned config used for the functional golden model.
# Timing/energy simulation uses the full Llama shapes (rust/src/config);
# functional numerics are validated at this scale (DESIGN.md substitutions).
GOLDEN_CFG = model.LayerConfig(
    hidden=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    intermediate=1024,
    lora_rank=8,
    lora_targets=("q", "v"),
    kv_capacity=512,
)
PREFILL_T = 64
SEED = 20260710


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_entries(tree, data_dir: pathlib.Path, prefix: str):
    """Dump every leaf of a pytree to data/<prefix>_<i>.bin; return metadata."""
    leaves = jax.tree_util.tree_leaves(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        name = f"{prefix}_{i:03d}"
        path = data_dir / f"{name}.bin"
        arr.tofile(path)
        entries.append(
            {
                "name": name,
                "file": f"data/{name}.bin",
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
            }
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    data = out / "data"
    data.mkdir(parents=True, exist_ok=True)

    cfg = GOLDEN_CFG
    key = jax.random.PRNGKey(SEED)
    kw, kx, kc = jax.random.split(key, 3)
    w = model.init_layer_weights(cfg, kw)

    manifest: dict = {
        "seed": SEED,
        "config": dataclasses.asdict(cfg),
        "modules": {},
    }

    # ---------------- decode_step ----------------
    x = jax.random.normal(kx, (cfg.hidden,), jnp.float32)
    k_cache = jnp.zeros((cfg.kv_capacity, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    # Pre-populate a short KV history so attention is non-trivial.
    hist = 37
    kh = jax.random.normal(kc, (hist, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    vh = jax.random.normal(kw, (hist, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    k_cache = k_cache.at[:hist].set(kh)
    v_cache = v_cache.at[:hist].set(vh)
    pos = jnp.int32(hist)

    fd = model.jitted_decode_step(cfg)
    lowered = fd.lower(w, x, k_cache, v_cache, pos)
    (out / "decode_step.hlo.txt").write_text(to_hlo_text(lowered))
    y, k_new, v_new = fd(w, x, k_cache, v_cache, pos)

    manifest["modules"]["decode_step"] = {
        "hlo": "decode_step.hlo.txt",
        "params": _leaf_entries((w, x, k_cache, v_cache, pos), data, "ds_in"),
        "outputs": _leaf_entries((y, k_new, v_new), data, "ds_out"),
    }

    # ---------------- prefill_block ----------------
    xb = jax.random.normal(kc, (PREFILL_T, cfg.hidden), jnp.float32)
    pos0 = jnp.int32(0)
    fp = model.jitted_prefill_block(cfg)
    lowered = fp.lower(w, xb, pos0)
    (out / "prefill_block.hlo.txt").write_text(to_hlo_text(lowered))
    yb, kb, vb = fp(w, xb, pos0)
    manifest["modules"]["prefill_block"] = {
        "hlo": "prefill_block.hlo.txt",
        "params": _leaf_entries((w, xb, pos0), data, "pf_in"),
        "outputs": _leaf_entries((yb, kb, vb), data, "pf_out"),
    }

    # ---------------- lora_matmul (bare PE-pair kernel) ----------------
    T, K, M, R = 4, 512, 512, 8
    kk = jax.random.split(key, 4)
    xs = jax.random.normal(kk[0], (T, K), jnp.float32)
    wf = jax.random.normal(kk[1], (M, K), jnp.float32) / np.sqrt(K)
    wq, sc = ref.quantize_weight_tiles(wf)
    a = jax.random.normal(kk[2], (R, K), jnp.float32) * 0.05
    b = jax.random.normal(kk[3], (M, R), jnp.float32) * 0.05

    def fn(xs, wq, sc, a, b):
        return pim_lora_matmul(xs, wq, sc, a, b)

    jf = jax.jit(fn)
    lowered = jf.lower(xs, wq, sc, a, b)
    (out / "lora_matmul.hlo.txt").write_text(to_hlo_text(lowered))
    ym = jf(xs, wq, sc, a, b)
    manifest["modules"]["lora_matmul"] = {
        "hlo": "lora_matmul.hlo.txt",
        "params": _leaf_entries((xs, wq, sc, a, b), data, "lm_in"),
        "outputs": _leaf_entries((ym,), data, "lm_out"),
    }

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    n_files = len(list(data.iterdir()))
    print(f"wrote 3 HLO modules + manifest + {n_files} tensors to {out}/")


if __name__ == "__main__":
    main()
