#!/usr/bin/env python3
"""Python mirror of the Rust cost pipeline and serving event loop.

This container ships no Rust toolchain, so numeric changes to the crate
are cross-validated against this mirror (the same approach PR 1/PR 2
used). It reproduces, operation-for-operation (IEEE-754 doubles and exact
integer arithmetic, same order of operations):

  * config defaults (SystemConfig, CalibConstants, models, LoRA)
  * mapping::optimize_layer / map_model (shape search + shelf packing)
  * noc closed-form spanning-tree metrics + AnalyticNoc
  * isa program structures and sim::cost::{instr,phase,program}_cost
  * dataflow::{decode,prefill,reprogram}_program
  * sim::LayerCostModel (geometric kv sampling + lerp; sharded variant
    samples chip 0's program slice)
  * mapping::shard (split_even work shares) + dataflow::shard_program_slice
  * noc::chipmesh (chip-ring all-reduce closed form)
  * sim::engine::Simulator::run_sharded_batched (cycles + energy ledger,
    n_chips tensor-parallel sharding; 1 chip collapses bit-for-bit)
  * coordinator::Server event loop — monolithic AND chunked prefill,
    batched decode, Fcfs / AdapterAffinity(/max_run_len) / SJF policies,
    sharded decode/prefill costs
  * coordinator::PrefixCache — cross-request KV prefix reuse: the
    preamble trie over pool pages, hit/miss block ledger (u64 prefill
    FLOP conservation), RRAM-passes-saved credit, release-on-retire/
    preempt refcounting

Running it regenerates the instruction-count proxy values committed in
rust/benches/baselines/sim_proxy.txt and re-checks the serving gates the
new benches/tests assert (chunked-prefill stall/ITL reductions, batch-1
bit-matches, conservation, starvation bound).

Usage:  python3 python/tools/sim_mirror.py [--check]
"""

import heapq
import math
import os
import sys
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# config mirrors
# ---------------------------------------------------------------------------

MESH = 32
TILE = 256

SYS = dict(
    freq_hz=1.0e9,
    link_bits=64,
    mesh_dim=32,
    rram_rows=256,
    rram_cols=256,
    sram_rows=256,
    sram_cols=64,
    scratchpad_bytes=32 * 1024,
    fifo_bytes=128,
    dmac_per_router=16,
    io_pairs=6,
    weight_bits=8,
    rram_uw=120.0,
    sram_uw=950.0,
    spad_uw=42.0,
    router_uw=103.0,
)

CAL = dict(
    rram_pass_cycles=96,
    sram_pass_cycles=24,
    hop_cycles=2,
    link_efficiency=0.80,
    scratchpad_latency_cycles=3,
    dmac_macs_per_cycle=1.0,
    softmax_cycles_per_elem=2.0,
    sram_write_bytes_per_cycle=4.0,
    collective_congestion=1.15,
    nmc_issue_cycles=4,
    d2d_latency_cycles=40,
    d2d_bytes_per_cycle=16.0,
    d2d_sf_bytes_per_cycle=4.0,
    retention_frac=0.010,
    router_idle_frac=0.05,
    idle_ungated_frac=0.20,
    hop_energy_pj_per_byte=0.35,
    dmac_energy_pj_per_mac=0.08,
    rram_pass_energy_nj=11.5,
    sram_pass_energy_nj=1.9,
    scratchpad_pj_per_byte=0.45,
    ct_static_w=0.05,
)

PES_PER_CT = MESH * MESH
LINK_BPC = SYS["link_bits"] // 8
EFF_BW = CAL["link_efficiency"] * float(LINK_BPC)
CYCLE_S = 1.0 / SYS["freq_hz"]

MODELS = {
    "1b": dict(layers=16, hidden=2048, n_heads=32, n_kv_heads=8, head_dim=64,
               intermediate=8192),
    "8b": dict(layers=32, hidden=4096, n_heads=32, n_kv_heads=8, head_dim=128,
               intermediate=14336),
    "13b": dict(layers=40, hidden=5120, n_heads=40, n_kv_heads=40, head_dim=128,
                intermediate=13824),
}


def q_dim(m):
    return m["n_heads"] * m["head_dim"]


def kv_dim(m):
    return m["n_kv_heads"] * m["head_dim"]


def lora_layer_params(m, targets, rank=8):
    total = 0
    for t in targets:
        if t == "Q":
            mm, kk = q_dim(m), m["hidden"]
        elif t in ("K", "V"):
            mm, kk = kv_dim(m), m["hidden"]
        else:
            mm, kk = m["hidden"], q_dim(m)
        total += rank * (mm + kk)
    return total


# ---------------------------------------------------------------------------
# geometry + spanning-tree closed forms
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Rect:
    x0: int
    y0: int
    x1: int
    y1: int

    def width(self):
        return self.x1 - self.x0

    def height(self):
        return self.y1 - self.y0

    def count(self):
        return self.width() * self.height()

    def center(self):
        return ((self.x0 + self.x1) // 2, (self.y0 + self.y1) // 2)


GROUP = Rect(0, 0, MESH, MESH)
ENTRY = (0, 0)


def manhattan(a, b):
    return abs(a[0] - b[0]) + abs(a[1] - b[1])


def _entry(root, dest):
    return (min(max(root[0], dest.x0), dest.x1 - 1),
            min(max(root[1], dest.y0), dest.y1 - 1))


def tree_depth(root, dest):
    e = _entry(root, dest)
    trunk = manhattan(root, e)
    dx = max(e[0] - dest.x0, dest.x1 - 1 - e[0])
    dy = max(e[1] - dest.y0, dest.y1 - 1 - e[1])
    return trunk + dx + dy


def tree_edges(root, dest):
    e = _entry(root, dest)
    trunk = manhattan(root, e)
    return dest.count() + trunk - 1


def tree_fan_in(root, dest):
    e = _entry(root, dest)
    horiz = int(e[0] > dest.x0) + int(e[0] + 1 < dest.x1)
    vert = int(e[1] > dest.y0) + int(e[1] + 1 < dest.y1)
    spine = 1 + vert
    return max(horiz + vert, spine, 1)


def noc_stream(bytes_):
    return math.ceil(bytes_ / EFF_BW)


def noc_unicast(frm, to, bytes_):
    dist = manhattan(frm, to)
    return (CAL["hop_cycles"] * dist + noc_stream(bytes_), bytes_ * dist)


def noc_broadcast(root, dest, bytes_):
    depth = tree_depth(root, dest)
    edges = tree_edges(root, dest)
    cycles = CAL["hop_cycles"] * depth + math.ceil(
        float(noc_stream(bytes_)) * CAL["collective_congestion"])
    return (cycles, bytes_ * edges)


def noc_reduce(src, root, bytes_):
    depth = tree_depth(root, src)
    edges = tree_edges(root, src)
    fan = float(max(tree_fan_in(root, src), 1))
    cycles = CAL["hop_cycles"] * depth + math.ceil(
        float(noc_stream(bytes_)) * fan * CAL["collective_congestion"])
    return (cycles, bytes_ * edges)


# ---------------------------------------------------------------------------
# mapping mirror
# ---------------------------------------------------------------------------

MATRICES = ["WQ", "WK", "WV", "WO", "WGate", "WUp", "WDown"]
ATTN = {"WQ", "WK", "WV", "WO"}


@dataclass
class Shape:
    id: str
    m: int
    k: int

    def n_mt(self):
        return -(-self.m // TILE)

    def n_kt(self):
        return -(-self.k // TILE)

    def tiles(self):
        return self.n_mt() * self.n_kt()


@dataclass
class Region:
    id: str
    ct: int
    rect: Rect
    mt0: int
    mt1: int
    kt0: int
    kt1: int

    def n_kt(self):
        return self.kt1 - self.kt0

    def n_mt(self):
        return self.mt1 - self.mt0


def layer_matrices(m):
    h, q, kv, it = m["hidden"], q_dim(m), kv_dim(m), m["intermediate"]
    return [Shape("WQ", q, h), Shape("WK", kv, h), Shape("WV", kv, h),
            Shape("WO", h, q), Shape("WGate", it, h), Shape("WUp", it, h),
            Shape("WDown", h, it)]


class ShelfPacker:
    def __init__(self, mesh):
        self.mesh = mesh
        self.ct = 0
        self.shelf_y = 0
        self.shelf_h = 0
        self.cursor_x = 0

    def place(self, w, h):
        if w > self.mesh or h > self.mesh:
            return None
        if self.cursor_x + w <= self.mesh and self.shelf_y + h <= self.mesh:
            rect = Rect(self.cursor_x, self.shelf_y, self.cursor_x + w,
                        self.shelf_y + h)
            self.cursor_x += w
            self.shelf_h = max(self.shelf_h, h)
            return (self.ct, rect)
        if self.shelf_y + self.shelf_h + h <= self.mesh:
            self.shelf_y += self.shelf_h
            self.cursor_x = 0
            self.shelf_h = h
            rect = Rect(0, self.shelf_y, w, self.shelf_y + h)
            self.cursor_x = w
            return (self.ct, rect)
        self.ct += 1
        self.shelf_y = 0
        self.cursor_x = 0
        self.shelf_h = h
        rect = Rect(0, 0, w, h)
        self.cursor_x = w
        return (self.ct, rect)


def place_matrix(shape, region_w, packer, out):
    n_mt, n_kt = shape.n_mt(), shape.n_kt()
    w = max(min(region_w, n_kt), 1)
    rows_per_mt = -(-n_kt // w)
    max_mt_per_slab = max(packer.mesh // rows_per_mt, 1)
    mt0 = 0
    while mt0 < n_mt:
        mt1 = min(mt0 + max_mt_per_slab, n_mt)
        h = (mt1 - mt0) * rows_per_mt
        placed = packer.place(w, h)
        if placed is None:
            return False
        ct, rect = placed
        out.append(Region(shape.id, ct, rect, mt0, mt1, 0, n_kt))
        mt0 = mt1
    return True


def layout_comm_cost(regions):
    cost = 0
    for r in regions:
        bcast = (r.n_kt() * TILE * 4)
        cost += noc_broadcast(ENTRY, r.rect, bcast)[0]
        red = (r.n_mt() * TILE * 4)
        cost += noc_reduce(r.rect, r.rect.center(), red)[0]
    return cost


def optimize_layer(matrices):
    orderings = [list(range(len(matrices)))]
    idx = sorted(range(len(matrices)),
                 key=lambda i: (matrices[i].id not in ATTN, matrices[i].tiles()))
    orderings.append(idx)
    best = None
    for ordering in orderings:
        for w_div in (1, 2, 4, 8):
            packer = ShelfPacker(MESH)
            regions = []
            ok = True
            for i in ordering:
                mshape = matrices[i]
                w = min(max(-(-mshape.n_kt() // w_div), 1), MESH)
                if not place_matrix(mshape, w, packer, regions):
                    ok = False
                    break
            if not ok:
                continue
            n_cts = max(r.ct for r in regions) + 1
            cost = layout_comm_cost(regions) + n_cts * 1_000_000
            if best is None or cost < best[0]:
                best = (cost, regions, n_cts)
    return best[1], best[2]


@dataclass
class LayerMapping:
    ct_base: int
    n_cts: int
    regions: list
    kv_ring_routers: int
    kv_token_bytes: int
    lora_bytes: int


def map_model(model, targets):
    m = MODELS[model]
    regions, n_cts = optimize_layer(layer_matrices(m))
    kv_ring = n_cts * PES_PER_CT
    kv_tok = 2 * kv_dim(m) * 2
    lora_bytes = lora_layer_params(m, targets) * 4
    return LayerMapping(0, n_cts, regions, max(kv_ring, 1), kv_tok, lora_bytes)


# ---------------------------------------------------------------------------
# program generation + costing mirror
# ---------------------------------------------------------------------------

U16 = 0xFFFF
U32 = 0xFFFFFFFF


@dataclass
class Cost:
    cycles: int = 0
    rram_passes: int = 0
    sram_passes: int = 0
    dmac_macs: int = 0
    softmax_elems: int = 0
    spad_bytes: int = 0
    net_byte_hops: int = 0
    reprog_bytes: int = 0
    d2d_bytes: int = 0

    def merge_parallel(self, o):
        self.cycles = max(self.cycles, o.cycles)
        self._merge_events(o)

    def _merge_events(self, o):
        self.rram_passes += o.rram_passes
        self.sram_passes += o.sram_passes
        self.dmac_macs += o.dmac_macs
        self.softmax_elems += o.softmax_elems
        self.spad_bytes += o.spad_bytes
        self.net_byte_hops += o.net_byte_hops
        self.reprog_bytes += o.reprog_bytes
        self.d2d_bytes += o.d2d_bytes


def instr_cost(i):
    c = Cost()
    kind = i[0]
    if kind == "bcast":
        _, root, dest, bytes_ = i
        cyc, bh = noc_broadcast(root, dest, bytes_)
        c.cycles, c.net_byte_hops = cyc, bh
    elif kind == "reduce":
        _, src, root, bytes_ = i
        cyc, bh = noc_reduce(src, root, bytes_)
        c.cycles, c.net_byte_hops = cyc, bh
    elif kind == "ucast":
        _, frm, to, bytes_ = i
        cyc, bh = noc_unicast(frm, to, bytes_)
        c.cycles, c.net_byte_hops = cyc, bh
    elif kind == "smac":
        _, pes, passes = i
        c.cycles = passes * CAL["rram_pass_cycles"] + CAL["scratchpad_latency_cycles"]
        c.rram_passes = pes.count() * passes
    elif kind == "srmac":
        _, pes, passes = i
        c.cycles = passes * CAL["sram_pass_cycles"]
        c.sram_passes = pes.count() * passes
    elif kind == "dmac":
        _, routers, macs = i
        units = float(routers.count() * SYS["dmac_per_router"])
        c.cycles = math.ceil(float(macs) / (units * CAL["dmac_macs_per_cycle"]))
        c.dmac_macs = macs
    elif kind == "softmax":
        _, routers, elems = i
        c.cycles = math.ceil(float(elems) * CAL["softmax_cycles_per_elem"]
                             / float(routers.count())) \
            + CAL["hop_cycles"] * (routers.width() + routers.height())
        c.softmax_elems = elems
    elif kind in ("sprd", "spwr"):
        _, routers, bytes_ = i
        per_router = math.ceil(float(bytes_) / float(routers.count()))
        c.cycles = CAL["scratchpad_latency_cycles"] + math.ceil(
            float(per_router) / float(LINK_BPC))
        c.spad_bytes = bytes_
    elif kind == "reprog":
        _, pes, bytes_ = i
        per_macro = math.ceil(float(bytes_) / float(pes.count()))
        c.cycles = math.ceil(float(per_macro) / CAL["sram_write_bytes_per_cycle"])
        c.reprog_bytes = bytes_
    elif kind == "d2d":
        _, bytes_, hops = i
        if hops >= 1:
            c.cycles = hops * (CAL["d2d_latency_cycles"]
                               + math.ceil(float(bytes_) / CAL["d2d_sf_bytes_per_cycle"]))
        else:
            c.cycles = CAL["d2d_latency_cycles"] + math.ceil(
                float(bytes_) / CAL["d2d_bytes_per_cycle"])
        c.d2d_bytes = bytes_ * max(hops, 1)
    else:
        raise ValueError(kind)
    return c


def program_cost(prog):
    """prog: list of (overlaps_prev, [instr...])."""
    total = Cost()
    prev_cycles = 0
    for overlaps, instrs in prog:
        c = Cost()
        for i in instrs:
            c.merge_parallel(instr_cost(i))
        if overlaps:
            extra = max(c.cycles - prev_cycles, 0)
            total.cycles += extra
            prev_cycles += extra
        else:
            total.cycles += c.cycles + CAL["nmc_issue_cycles"]
            prev_cycles = c.cycles
        total._merge_events(Cost(**{**c.__dict__, "cycles": 0}))
    return total


def _region_rect(lm, mid, ct):
    out = None
    for r in lm.regions:
        if r.id == mid and r.ct == ct:
            if out is None:
                out = r.rect
            else:
                out = Rect(min(out.x0, r.rect.x0), min(out.y0, r.rect.y0),
                           max(out.x1, r.rect.x1), max(out.y1, r.rect.y1))
    return out


def _each_ct(lm, mid):
    out = []
    for ct in range(lm.n_cts):
        r = _region_rect(lm, mid, ct)
        if r is not None:
            out.append((ct, r))
    return out


def _kt_of(lm, mid):
    kts = [r.n_kt() for r in lm.regions if r.id == mid]
    return max(kts) if kts else 0


def layer_program(model, targets, lm, tokens, kv_len):
    m = MODELS[model]
    t = tokens
    decode = tokens == 1
    f32b = 4
    prog = []

    def delivery(bytes_, rects):
        v = []
        hops = max(lm.n_cts, 1) if decode else 0
        v.append(("d2d", bytes_, hops))
        for _ct, rect in rects:
            v.append(("bcast", ENTRY, rect, bytes_))
        return v

    def smac_passes(mid):
        return min(max(_kt_of(lm, mid), 1) * t, U16)

    def reduce_phase(mid):
        return [("reduce", rect, rect.center(), min(TILE * 4 * t, U32))
                for _ct, rect in _each_ct(lm, mid)]

    qkv_rects = []
    for mid in ("WQ", "WK", "WV"):
        qkv_rects.extend(_each_ct(lm, mid))
    in_bytes = m["hidden"] * f32b * t
    prog.append((False, delivery(in_bytes, qkv_rects)))

    instrs = []
    for mid in ("WQ", "WK", "WV"):
        passes = smac_passes(mid)
        for _ct, rect in _each_ct(lm, mid):
            instrs.append(("smac", rect, passes))
    prog.append((True, instrs))

    if targets:
        instrs = []
        for tgt in targets:
            mid = {"Q": "WQ", "K": "WK", "V": "WV", "O": "WO"}[tgt]
            passes = min(2 * t, U16)
            for _ct, rect in _each_ct(lm, mid):
                instrs.append(("srmac", rect, passes))
        prog.append((True, instrs))

    instrs = []
    for mid in ("WQ", "WK", "WV"):
        instrs.extend(reduce_phase(mid))
    prog.append((False, instrs))

    kv_bytes = min(lm.kv_token_bytes * t, U32)
    prog.append((False, [("ucast", ENTRY, GROUP.center(), kv_bytes),
                         ("spwr", GROUP, kv_bytes)]))

    kv64 = kv_len
    score_macs = min(m["n_heads"] * m["head_dim"] * kv64 * tokens, U32)
    if decode:
        gather_bytes = min(m["n_heads"] * 4 * kv64, U32)
    else:
        clusters = -(-lm.n_cts // 2)
        gather_bytes = min(m["n_heads"] * 2 * kv64 * tokens // clusters, U32)
    kv_read_bytes = min(kv64 * kv_dim(m) * 2, U32)
    prog.append((False, [
        ("bcast", ENTRY, GROUP, q_dim(m) * f32b * t),
        ("sprd", GROUP, kv_read_bytes),
        ("dmac", GROUP, score_macs),
        ("ucast", ENTRY, GROUP.center(), gather_bytes),
    ]))

    elems = min(m["n_heads"] * kv64 * tokens, U32)
    prog.append((False, [("softmax", GROUP, elems)]))

    prog.append((False, [
        ("sprd", GROUP, kv_read_bytes),
        ("dmac", GROUP, score_macs),
        ("ucast", GROUP.center(), ENTRY, gather_bytes),
        ("ucast", GROUP.center(), ENTRY, q_dim(m) * f32b * t),
    ]))

    o_rects = _each_ct(lm, "WO")
    prog.append((False, delivery(q_dim(m) * f32b * t, o_rects)))
    instrs = [("smac", rect, smac_passes("WO")) for _ct, rect in o_rects]
    instrs.extend(reduce_phase("WO"))
    prog.append((True, instrs))

    mlp_rects = []
    for mid in ("WGate", "WUp"):
        mlp_rects.extend(_each_ct(lm, mid))
    prog.append((False, delivery(m["hidden"] * f32b * t, mlp_rects)))
    instrs = []
    for mid in ("WGate", "WUp"):
        for _ct, rect in _each_ct(lm, mid):
            instrs.append(("smac", rect, smac_passes(mid)))
        instrs.extend(reduce_phase(mid))
    prog.append((True, instrs))

    prog.append((False, [("softmax", GROUP, min(m["intermediate"] * tokens, U32))]))

    down_rects = _each_ct(lm, "WDown")
    prog.append((False, delivery(m["intermediate"] * f32b * t, down_rects)))
    instrs = [("smac", rect, smac_passes("WDown")) for _ct, rect in down_rects]
    instrs.extend(reduce_phase("WDown"))
    prog.append((True, instrs))

    prog.append((False, [("d2d", m["hidden"] * f32b * t, 1 if decode else 0)]))
    return prog


def decode_program(model, targets, lm, kv_len):
    return layer_program(model, targets, lm, 1, kv_len)


def prefill_program(model, targets, lm, block, kv_len):
    return layer_program(model, targets, lm, block, kv_len)


def reprogram_program(lm):
    bytes_ = min(lm.lora_bytes, U32)
    return [(False, [("d2d", bytes_, 0),
                     ("bcast", ENTRY, GROUP, bytes_),
                     ("reprog", GROUP, bytes_)])]


# ---------------------------------------------------------------------------
# sharding mirrors: mapping::shard, dataflow::shard_program_slice,
# noc::chipmesh (ShardConfig defaults: 250-cycle hop, 32 B/cycle links)
# ---------------------------------------------------------------------------

CHIP_HOP_CYCLES = 250
CHIP_LINK_BPC = 32.0
ALLREDUCES_PER_LAYER = 2


def share_of(total, chip, n):
    """mapping::shard::share_of — exact integer share of chip `chip`."""
    n = max(n, 1)
    return total // n + (1 if chip < total % n else 0)


def split_even(total, n):
    return [share_of(total, i, n) for i in range(max(n, 1))]


def shard_program_slice(prog, chip, n):
    """dataflow::shard_program_slice on the mirror's instr tuples."""
    out = []
    for overlaps, instrs in prog:
        ni = []
        for i in instrs:
            k = i[0]
            if k in ("smac", "srmac", "dmac", "softmax", "sprd", "spwr"):
                ni.append((k, i[1], share_of(i[2], chip, n)))
            elif k == "ucast":
                ni.append((k, i[1], i[2], share_of(i[3], chip, n)))
            else:
                ni.append(i)
        out.append((overlaps, ni))
    return out


def chip_all_reduce_cycles(n_chips, bytes_):
    """noc::ChipMesh::all_reduce_cycles (ring, 2(n-1) steps)."""
    if n_chips <= 1 or bytes_ == 0:
        return 0
    steps = 2 * (n_chips - 1)
    chunk = -(-bytes_ // n_chips)
    return steps * (CHIP_HOP_CYCLES + math.ceil(float(chunk) / CHIP_LINK_BPC))


def chip_all_reduce_link_bytes(n_chips, bytes_):
    if n_chips <= 1 or bytes_ == 0:
        return 0
    return 2 * (n_chips - 1) * (-(-bytes_ // n_chips))


def layer_all_reduce_cycles(n_chips, hidden, tokens):
    return ALLREDUCES_PER_LAYER * chip_all_reduce_cycles(n_chips, hidden * 4 * tokens)


def layer_all_reduce_link_bytes(n_chips, hidden, tokens):
    return ALLREDUCES_PER_LAYER * chip_all_reduce_link_bytes(n_chips, hidden * 4 * tokens)


def shard_kv_bytes_per_router(lm, n_chips, tokens, slots):
    """mapping::ShardPlan::kv_bytes_per_router."""
    kv_tok_chip = -(-lm.kv_token_bytes // max(n_chips, 1))
    return (-(-tokens // max(lm.kv_ring_routers, 1))) * kv_tok_chip * max(slots, 1)


def config_validate_kv(model, targets, ctx, batch, n_chips):
    """ExperimentConfig::validate's weight-estimate KV check (True = fits)."""
    m = MODELS[model]
    layer_weights = (q_dim(m) * m["hidden"] + 2 * kv_dim(m) * m["hidden"]
                     + m["hidden"] * q_dim(m) + 3 * m["intermediate"] * m["hidden"])
    cts = max(-(-layer_weights // (PES_PER_CT * 256 * 256)), 1)
    ring = cts * PES_PER_CT
    tokens = 2 * ctx
    kv_tok = -(-(2 * kv_dim(m) * 2) // max(n_chips, 1))
    per_router = (-(-tokens // ring)) * kv_tok * max(batch, 1)
    return per_router <= SYS["scratchpad_bytes"]


# ---------------------------------------------------------------------------
# layer cost model mirror (integer lerp + closed-form segment summation)
# ---------------------------------------------------------------------------

KV_SAMPLES = [0, 128, 256, 512, 1024, 1536, 2048, 3072, 4096, 8192]

COST_FIELDS = ("cycles", "rram_passes", "sram_passes", "dmac_macs",
               "softmax_elems", "spad_bytes", "net_byte_hops", "reprog_bytes",
               "d2d_bytes")


def lerp_round(a, b, j, d):
    """Rust sim::layer_model::lerp_round — exact rounded lerp, clamped at 0.

    max(0, floor((2*a*d + 2*(b-a)*j + d) / (2*d))); on this sample grid
    (power-of-two segment widths) it equals the historical f64
    `(a + (b-a)*j/d).round().max(0.0)` bit for bit.
    """
    num = 2 * a * d + 2 * (b - a) * j + d
    if num < 0:
        return 0
    return num // (2 * d)


def floor_sum(n, m, a, b):
    """sum_{i=0}^{n-1} floor((a*i + b)/m), m > 0 — Euclidean descent."""
    assert n >= 0 and m > 0
    ans = 0
    if a < 0:
        a2 = a % m
        ans -= n * (n - 1) // 2 * ((a2 - a) // m)
        a = a2
    if b < 0:
        b2 = b % m
        ans -= n * ((b2 - b) // m)
        b = b2
    while True:
        if a >= m:
            ans += n * (n - 1) // 2 * (a // m)
            a %= m
        if b >= m:
            ans += n * (b // m)
            b %= m
        y_max = a * n + b
        if y_max < m:
            break
        n = y_max // m
        b = y_max % m
        m, a = a, m
    return ans


def sum_lerp(a, b, d, j0, j1):
    """sum_{j in [j0, j1)} lerp_round(a, b, j, d) in closed form."""
    if j1 <= j0:
        return 0
    delta = b - a
    c = 2 * a * d + d
    hi = j1
    if delta < 0:
        j_pos = c // (-2 * delta)
        hi = max(min(j1, j_pos + 1), j0)
    if hi <= j0:
        return 0
    n = hi - j0
    return floor_sum(n, 2 * d, 2 * delta, 2 * delta * j0 + c)


class LayerCostModel:
    def __init__(self, model, targets, lm, n_chips=1):
        def prog(kv):
            p = decode_program(model, targets, lm, kv)
            return p if n_chips <= 1 else shard_program_slice(p, 0, n_chips)

        self.samples = [(kv, program_cost(prog(kv))) for kv in KV_SAMPLES]

    def _bracket(self, kv_len):
        pts = self.samples
        idx = None
        for i, (k, _) in enumerate(pts):
            if k >= kv_len:
                idx = i
                break
        if idx == 0:
            return None
        if idx is None:
            return pts[-2], pts[-1]
        return pts[idx - 1], pts[idx]

    def eval_cycles(self, kv_len):
        br = self._bracket(kv_len)
        if br is None:
            return self.samples[0][1].cycles
        (k0, c0), (k1, c1) = br
        return lerp_round(c0.cycles, c1.cycles, kv_len - k0, k1 - k0)

    def _segments(self, kv0, n):
        """Yield (lo, hi, (k0, c0), (k1, c1)) covering [kv0, kv0+n)."""
        pts = self.samples
        m = len(pts)
        hi = kv0 + n
        lo = kv0
        while lo < hi:
            i = 0
            for idx in range(m - 1, -1, -1):
                if pts[idx][0] <= lo:
                    i = min(idx, m - 2)
                    break
            seg_end = hi if i == m - 2 else min(hi, pts[i + 1][0])
            yield lo, seg_end, pts[i], pts[i + 1]
            lo = seg_end

    def sum_window(self, kv0, n):
        """Closed-form sum of every field over [kv0, kv0+n) — mirrors
        LayerCostModel::sum_window (O(#segments) floor-sums)."""
        acc = Cost()
        for lo, hi, (k0, c0), (k1, c1) in self._segments(kv0, n):
            d = k1 - k0
            for fld in COST_FIELDS:
                setattr(acc, fld, getattr(acc, fld)
                        + sum_lerp(getattr(c0, fld), getattr(c1, fld), d,
                                   lo - k0, hi - k0))
        return acc

    def sum_cycles_window(self, kv0, n):
        acc = 0
        for lo, hi, (k0, c0), (k1, c1) in self._segments(kv0, n):
            acc += sum_lerp(c0.cycles, c1.cycles, k1 - k0, lo - k0, hi - k0)
        return acc


# ---------------------------------------------------------------------------
# engine mirror (run_batched: cycles + energy)
# ---------------------------------------------------------------------------

def srpg_plan(n_groups, reprog_cycles, group_start, enabled):
    reprog_ct_cycles = float(reprog_cycles * n_groups) * 0.0  # set below
    if not enabled:
        total = reprog_cycles * n_groups
        return total, 0
    ttft_penalty = reprog_cycles
    stalls = 0
    reprog_done = reprog_cycles
    for g in range(1, n_groups):
        end = reprog_done + reprog_cycles
        wave = ttft_penalty + group_start[g] + stalls
        if end > wave:
            stalls += end - wave
        reprog_done = end
    return ttft_penalty, stalls


def step_cycles(per_layer_list, n_layers, overhead):
    s = sum(per_layer_list)
    mx = max(per_layer_list)
    b = len(per_layer_list)
    return s + (n_layers - 1) * mx + (b - 1) * overhead


class Ledger:
    def __init__(self):
        self.rram = self.sram = self.spad = self.router = 0.0
        self.dmac = self.net = self.ret = self.static = 0.0
        self.span_cycles = 0

    def post_cost_events(self, c, scale=1):
        """One post of `c`'s event counters scaled by `scale` — the u64
        counters multiply exactly *before* the float conversion (mirrors
        PhaseCost::events_scaled + post)."""
        self.rram += float(c.rram_passes * scale) * CAL["rram_pass_energy_nj"] * 1e-9
        self.sram += float(c.sram_passes * scale) * CAL["sram_pass_energy_nj"] * 1e-9
        self.dmac += float((c.dmac_macs + c.softmax_elems * 4) * scale) \
            * CAL["dmac_energy_pj_per_mac"] * 1e-12
        self.spad += float(c.spad_bytes * scale) * CAL["scratchpad_pj_per_byte"] * 1e-12
        self.net += float(c.net_byte_hops * scale) * CAL["hop_energy_pj_per_byte"] * 1e-12
        self.sram += float(c.reprog_bytes * scale) * CAL["scratchpad_pj_per_byte"] * 1e-12
        self.net += float(c.d2d_bytes * 4 * scale) * CAL["hop_energy_pj_per_byte"] * 1e-12

    def post_sram_writes(self, bytes_):
        self.sram += float(bytes_) * CAL["scratchpad_pj_per_byte"] * 1e-12

    def post_state(self, state, n_cts, cycles):
        dt = float(cycles) * CYCLE_S * n_cts
        pairs = float(PES_PER_CT)
        sram_w = SYS["sram_uw"] * 1e-6
        spad_w = SYS["spad_uw"] * 1e-6
        rram_w = SYS["rram_uw"] * 1e-6
        rtr_w = SYS["router_uw"] * 1e-6
        ret = CAL["retention_frac"]
        if state == "active":
            self.ret += dt * pairs * (sram_w + spad_w) * ret
            self.router += dt * pairs * rtr_w * CAL["router_idle_frac"]
            self.rram += dt * pairs * rram_w * CAL["router_idle_frac"]
            self.static += dt * CAL["ct_static_w"]
        elif state == "gated":
            self.ret += dt * pairs * (sram_w + spad_w) * ret
        elif state == "idle_ungated":
            idle = CAL["idle_ungated_frac"]
            self.ret += dt * pairs * (sram_w + spad_w) * ret
            self.router += dt * pairs * rtr_w * idle
            self.rram += dt * pairs * rram_w * idle
            self.sram += dt * pairs * sram_w * idle
            self.spad += dt * pairs * spad_w * idle
            self.static += dt * CAL["ct_static_w"]
        elif state == "reprogramming":
            self.ret += dt * pairs * spad_w * ret
            self.sram += dt * pairs * sram_w * 0.6
            self.static += dt * CAL["ct_static_w"] * 0.5

    def total_j(self):
        return (self.rram + self.sram + self.spad + self.router + self.dmac
                + self.net + self.ret + self.static)

    def avg_power_w(self):
        t = float(self.span_cycles) * CYCLE_S
        return self.total_j() / t if t > 0 else 0.0


def step_cycles_uniform(per_layer, b, n_layers, overhead):
    """sim::cost::pipelined_step_cycles_uniform."""
    return (b + n_layers - 1) * per_layer + (b - 1) * overhead


def run_batched(model, targets, ctx, batch=1, srpg=True, overhead=64, n_chips=1,
                closed_form=True, out_tokens=None):
    """Mirror of Simulator::run_sharded_batched (n_chips=1: run_batched).

    closed_form=True mirrors the default O(#segments) decode summation;
    False mirrors run_sharded_batched_reference (the retained per-token
    loop). Both post the decode totals through the same scaled single
    posts, so the results are bit-identical (gated in --check)."""
    m = MODELS[model]
    lm = map_model(model, targets)
    b = max(batch, 1)
    nc = max(n_chips, 1)
    hidden = m["hidden"]
    ledger = Ledger()
    n_groups = m["layers"]
    cts_per_group = lm.n_cts
    total_cts = n_groups * cts_per_group * nc

    reprog = program_cost(reprogram_program(lm))
    block = min(128, max(ctx, 1))
    n_blocks = -(-ctx // block)
    stage_cost = []
    stage_compute = []
    stage_events = []
    prefill_ar_link = 0
    for bi in range(n_blocks):
        this_block = ctx - bi * block if bi + 1 == n_blocks else block
        kvv = bi * block + this_block // 2
        prog = prefill_program(model, targets, lm, this_block, max(kvv, 1))
        c = program_cost(prog)
        compute = c.cycles if nc == 1 else program_cost(
            shard_program_slice(prog, 0, nc)).cycles
        stage_cost.append(compute + layer_all_reduce_cycles(nc, hidden, this_block))
        stage_compute.append(compute)
        prefill_ar_link += layer_all_reduce_link_bytes(nc, hidden, this_block)
        stage_events.append(c)
    layer_prefill_cycles = sum(stage_cost)
    layer_prefill_compute = sum(stage_compute)
    group_start = [l * layer_prefill_cycles for l in range(n_groups)]
    prefill_makespan = layer_prefill_cycles * n_groups * b
    ttft_penalty, stalls = srpg_plan(n_groups, reprog.cycles, group_start, srpg)
    ttft_cycles = ttft_penalty + prefill_makespan + stalls

    prefill_events = Cost()
    for c in stage_events:
        prefill_events._merge_events(c)
    ledger.post_cost_events(prefill_events, scale=n_groups * b)
    ledger.post_sram_writes(reprog.reprog_bytes * n_groups)
    if nc > 1:
        ledger.net += float(prefill_ar_link * (n_groups * b) * 4) \
            * CAL["hop_energy_pj_per_byte"] * 1e-12

    active_ct = float(layer_prefill_compute) * float(n_groups * cts_per_group * b * nc)
    total_ct = float(ttft_cycles) * float(total_cts)
    reprog_ct = float(reprog.cycles * n_groups) * float(cts_per_group) * float(nc)
    idle_ct = max(total_ct - active_ct - reprog_ct, 0.0)
    idle_state = "gated" if srpg else "idle_ungated"
    ledger.post_state("active", active_ct, 1)
    ledger.post_state(idle_state, idle_ct, 1)
    ledger.post_state("reprogramming", reprog_ct, 1)

    model_lcm = LayerCostModel(model, targets, lm)
    shard_lcm = model_lcm if nc == 1 else LayerCostModel(model, targets, lm, nc)
    ar_dec = layer_all_reduce_cycles(nc, hidden, 1)
    ar_dec_link = layer_all_reduce_link_bytes(nc, hidden, 1)
    out = ctx if out_tokens is None else out_tokens

    # ---- decode totals (u64-exact, either evaluation mode) ---------------
    if closed_form and out > 0:
        events = model_lcm.sum_window(ctx, out)
        compute_total = events.cycles if nc == 1 \
            else shard_lcm.sum_cycles_window(ctx, out)
        decode_total = (b + n_groups - 1) * (compute_total + out * ar_dec) \
            + out * ((b - 1) * overhead)
    else:
        events = Cost()
        compute_total = 0
        decode_total = 0
        for i in range(out):
            kvv = ctx + i
            ev = lerped_cost(model_lcm, kvv)
            compute = ev.cycles if nc == 1 else shard_lcm.eval_cycles(kvv)
            decode_total += step_cycles_uniform(compute + ar_dec, b, n_groups,
                                                overhead)
            compute_total += compute
            events._merge_events(ev)
            events.cycles += ev.cycles

    # ---- decode energy: scaled single posts ------------------------------
    if out > 0:
        ledger.post_cost_events(events, scale=n_groups * b)
        if nc > 1:
            ledger.net += float(ar_dec_link * (n_groups * b * out) * 4) \
                * CAL["hop_energy_pj_per_byte"] * 1e-12
        if b == 1 and nc == 1:
            active = float(decode_total) * float(cts_per_group)
            idle = float(decode_total) * float((n_groups - 1) * cts_per_group)
        else:
            active_int = b * (n_groups * nc) * compute_total * cts_per_group
            total_int = decode_total * (n_groups * cts_per_group * nc)
            active = float(active_int)
            idle = float(max(total_int - active_int, 0))
        ledger.post_state("active", active, 1)
        ledger.post_state(idle_state, idle, 1)

    total_cycles = ttft_cycles + decode_total
    ledger.span_cycles = total_cycles
    ttft_s = float(ttft_cycles) * CYCLE_S
    itl_ms = float(decode_total) / float(out) * CYCLE_S * 1e3 if out else 0.0
    total_s = ttft_s + float(decode_total) * CYCLE_S
    tokens = float((ctx + out) * b)
    tput = tokens / total_s
    power = ledger.avg_power_w()
    return dict(ttft_s=ttft_s, itl_ms=itl_ms, throughput=tput, power=power,
                eff=tput / max(power, 1e-12), energy=ledger.total_j(),
                cycles=total_cycles)


def lerped_cost(lcm, kv_len):
    """Full PhaseCost lerp (mirrors LayerCostModel::eval, integer form)."""
    br = lcm._bracket(kv_len)
    if br is None:
        return lcm.samples[0][1]
    (k0, c0), (k1, c1) = br
    out = Cost()
    for fld in COST_FIELDS:
        setattr(out, fld,
                lerp_round(getattr(c0, fld), getattr(c1, fld), kv_len - k0, k1 - k0))
    return out


def lerped_cost_f64(lcm, kv_len):
    """The historical f64 lerp — kept to gate the integer-form transition
    (bit-equal on this sample grid: power-of-two segment widths keep the
    f64 arithmetic exact)."""
    br = lcm._bracket(kv_len)
    if br is None:
        return lcm.samples[0][1]
    (k0, c0), (k1, c1) = br
    f = (float(kv_len) - float(k0)) / (float(k1) - float(k0))

    def lerp(a, bb):
        return int(math.floor(max(float(a) + (float(bb) - float(a)) * f, 0.0) + 0.5))

    out = Cost()
    for fld in COST_FIELDS:
        setattr(out, fld, lerp(getattr(c0, fld), getattr(c1, fld)))
    return out


# ---------------------------------------------------------------------------
# serving event-loop mirror (monolithic + chunked prefill)
# ---------------------------------------------------------------------------

@dataclass
class Req:
    id: int
    adapter: int
    inp: int
    out: int
    arrival: float = 0.0
    preamble: object = None


@dataclass
class Slot:
    req: Req
    generated: int = 0
    start_s: float = 0.0
    swap: bool = False
    ttft_s: float = 0.0
    decode_cycles: int = 0
    stall_s: float = 0.0
    pending_stall_s: float = 0.0
    admit_seq: int = 0
    shared_tokens: int = 0


@dataclass
class Job:
    req: Req
    swap: bool
    start_s: float
    reprog_s: float
    cum: list
    done: int = 0
    external_s: float = 0.0
    admit_seq: int = 0
    cum_tokens: list = field(default_factory=list)
    shared_tokens: int = 0

    def advance(self):
        end = self.start_s + self.external_s + (self.reprog_s + self.cum[self.done])
        self.done += 1
        return end

    def is_done(self):
        return self.done >= len(self.cum)

    def tokens_done(self):
        return 0 if self.done == 0 else self.cum_tokens[self.done - 1]

    def ttft(self):
        return (self.reprog_s + self.cum[-1]) + self.external_s

    def to_slot(self):
        return Slot(self.req, 0, self.start_s, self.swap, self.ttft(),
                    admit_seq=self.admit_seq, shared_tokens=self.shared_tokens)


class KvPoolMirror:
    """Counter-level mirror of coordinator::KvPool. Page *identities*
    (the min-heap free list) never leak into any blessed value, so the
    mirror tracks only per-owner page counts and the shared counters."""

    def __init__(self, page_tokens, capacity_pages):
        self.page_tokens = page_tokens
        self.capacity = capacity_pages
        self.held = {}
        self.used = 0
        self.allocs = 0
        self.frees = 0
        self.peak = 0

    def pages_for(self, tokens):
        return -(-tokens // self.page_tokens)

    def free_pages(self):
        return self.capacity - self.used

    def alloc(self, owner, n):
        # Zero-page allocations are true no-ops: registering the owner
        # anyway would leave a phantom holder in the held map (the bug the
        # PR 8 sweep fixed — fully prefix-shared prompts need 0 pages).
        if n == 0:
            return
        assert n <= self.free_pages(), "mirror pool overflow"
        self.held[owner] = self.held.get(owner, 0) + n
        self.used += n
        self.allocs += n
        self.peak = max(self.peak, self.used)

    def held_pages(self, owner):
        return self.held.get(owner, 0)

    def grow_to(self, owner, tokens):
        need = self.pages_for(tokens) - self.held.get(owner, 0)
        if need > 0:
            self.alloc(owner, need)

    def release(self, owner):
        n = self.held.pop(owner, 0)
        self.used -= n
        self.frees += n


def kv_pool_capacity_tokens(lm, n_chips=1):
    """mapping::ShardPlan::kv_capacity_tokens at the default scratchpad."""
    kv_tok_chip = max(-(-lm.kv_token_bytes // max(n_chips, 1)), 1)
    return (SYS["scratchpad_bytes"] // kv_tok_chip) * lm.kv_ring_routers


NODE_OWNER_BASE = 1 << 63


class PrefixCacheMirror:
    """Mirror of coordinator::PrefixCache: the preamble trie whose nodes
    each own one ref-counted pool page. Same intern/release semantics
    (hits are the leading interned run; zero-ref nodes free leaf->root),
    same lifetime counters."""

    def __init__(self):
        self.nodes = {}          # id -> [parent, key, refs, {key: child_id}]
        self.roots = {}
        self.next_node = 0
        self.interns = 0
        self.releases = 0
        self.hit_blocks = 0
        self.miss_blocks = 0
        self.nodes_created = 0
        self.nodes_freed = 0

    def probe(self, chain):
        hits = 0
        at = None
        for key in chain:
            nxt = self.roots.get(key) if at is None \
                else self.nodes[at][3].get(key)
            if nxt is None:
                break
            hits += 1
            at = nxt
        return hits, len(chain) - hits

    def intern(self, chain, pool):
        hits, misses = self.probe(chain)
        assert misses <= pool.free_pages(), "prefix intern over capacity"
        at = None
        for key in chain:
            existing = self.roots.get(key) if at is None \
                else self.nodes[at][3].get(key)
            if existing is not None:
                self.nodes[existing][2] += 1
                at = existing
            else:
                nid = self.next_node
                self.next_node += 1
                pool.alloc(NODE_OWNER_BASE | nid, 1)
                self.nodes[nid] = [at, key, 1, {}]
                if at is None:
                    self.roots[key] = nid
                else:
                    self.nodes[at][3][key] = nid
                self.nodes_created += 1
                at = nid
        self.interns += 1
        self.hit_blocks += hits
        self.miss_blocks += misses
        return hits

    def release(self, chain, pool):
        ids = []
        at = None
        for key in chain:
            at = self.roots[key] if at is None else self.nodes[at][3][key]
            ids.append(at)
        for nid in reversed(ids):
            node = self.nodes[nid]
            node[2] -= 1
            if node[2] == 0:
                del self.nodes[nid]
                if node[0] is None:
                    del self.roots[node[1]]
                else:
                    del self.nodes[node[0]][3][node[1]]
                pool.release(NODE_OWNER_BASE | nid)
                self.nodes_freed += 1
        self.releases += 1

    def live_nodes(self):
        return len(self.nodes)


class Policy:
    def __init__(self, kind, max_run_len=None):
        self.kind = kind
        self.max_run_len = max_run_len
        self.run_adapter = None
        self.run_len = 0

    def _note(self, waiting, pick):
        if pick is not None:
            a = waiting[pick].adapter
            if self.run_adapter == a:
                self.run_len += 1
            else:
                self.run_adapter = a
                self.run_len = 1
        return pick

    def pick(self, waiting, active, resident):
        """Admitting pick: records the choice in run-length state."""
        return self._note(waiting, self.peek(waiting, active, resident))

    def peek(self, waiting, active, resident):
        """Side-effect-free preview of pick (the fast-forward probe)."""
        if self.kind == "fcfs":
            if not waiting:
                return None
            if active is None or waiting[0].adapter == active:
                return 0
            return None
        if self.kind == "sjf":
            best = None
            for i, r in enumerate(waiting):
                if active is not None and r.adapter != active:
                    continue
                if best is None or (r.out, r.inp) < (waiting[best].out, waiting[best].inp):
                    best = i
            return best
        # affinity
        if not waiting:
            return None
        anchor = active if active is not None else resident
        if (self.max_run_len is not None and anchor is not None
                and self.run_adapter == anchor and self.run_len >= self.max_run_len
                and any(r.adapter != anchor for r in waiting)):
            if active is not None:
                return None
            return self._deepest(waiting, exclude=anchor)
        if anchor is not None:
            for i, r in enumerate(waiting):
                if r.adapter == anchor:
                    return i
            if active is not None:
                return None
        return self._deepest(waiting, exclude=None)

    @staticmethod
    def _deepest(waiting, exclude):
        groups = {}
        for i, r in enumerate(waiting):
            if exclude is not None and r.adapter == exclude:
                continue
            if r.adapter not in groups:
                groups[r.adapter] = [0, i]
            groups[r.adapter][0] += 1
        if not groups:
            return None
        best = None
        for cnt, first in groups.values():
            if best is None or cnt > best[0] or (cnt == best[0] and first < best[1]):
                best = (cnt, first)
        return best[1]


class Server:
    """Mirror of coordinator::Server (timing only, no energy)."""

    def __init__(self, model, targets, ctx, max_batch=1, policy="fcfs",
                 prefill_chunk=None, srpg=True, overhead=64, max_run_len=None,
                 n_chips=1, fast_forward=True, calendar=False,
                 continuous=False, kv_page_tokens=128, kv_pool_pages=None,
                 prefill_chips=None, decode_chips=None):
        self.m = MODELS[model]
        self.lm = map_model(model, targets)
        self.ctx = ctx
        self.n_layers = self.m["layers"]
        self.max_batch = max_batch
        self.overhead = overhead
        self.prefill_chunk = prefill_chunk
        self.policy = Policy(policy, max_run_len)
        # Disaggregated pools (mirrors ServerBuilder over a pooled
        # ShardConfig): admissions prefill on the prefill pool while the
        # decode pool steps — the prefill template is costed at the
        # prefill width, everything decode-side (layer model, all-reduce,
        # KV pool capacity) at the decode width, and each admitted
        # request's unshared prompt KV migrates pool-to-pool over one
        # ChipMesh transfer before it may join the decode batch.
        self.disagg = prefill_chips is not None
        if self.disagg:
            assert decode_chips is not None and prefill_chips >= 1 \
                and decode_chips >= 1, "pools set together, >= 1 chip each"
            assert continuous, "disagg serving requires continuous mode"
            assert prefill_chunk is None, \
                "disagg serving excludes chunked prefill"
            n_chips = prefill_chips + decode_chips
        nc = max(n_chips, 1)
        tw_p = prefill_chips if self.disagg else nc
        tw_d = decode_chips if self.disagg else nc
        reprog = program_cost(reprogram_program(self.lm))
        if srpg:
            self.reprog_s = float(reprog.cycles) * CYCLE_S
        else:
            self.reprog_s = float(reprog.cycles * self.n_layers) * CYCLE_S
        block = min(128, max(ctx, 1))
        n_blocks = -(-ctx // block)
        self.blocks = []
        # u64 twins of the prefill template: the prefix cache's FLOP
        # conservation ledger sums these exactly, and the per-block RRAM
        # passes are the energy credit of a skipped (hit) block.
        self.block_cycles = []
        self.block_rram = []
        for bi in range(n_blocks):
            this_block = ctx - bi * block if bi + 1 == n_blocks else block
            kvv = max(bi * block + this_block // 2, 1)
            prog = prefill_program(model, targets, self.lm, this_block, kvv)
            cost = (program_cost(prog) if tw_p == 1 else
                    program_cost(shard_program_slice(prog, 0, tw_p)))
            cycles = cost.cycles \
                + layer_all_reduce_cycles(tw_p, self.m["hidden"], this_block)
            self.blocks.append((this_block, float(cycles) * CYCLE_S))
            self.block_cycles.append(cycles)
            self.block_rram.append(cost.rram_passes)
        self.lcm = LayerCostModel(model, targets, self.lm, tw_d)
        self.ar_dec = layer_all_reduce_cycles(tw_d, self.m["hidden"], 1)
        self.fast_forward = fast_forward
        self.model_monotone = all(
            self.lcm.samples[i][1].cycles <= self.lcm.samples[i + 1][1].cycles
            for i in range(len(self.lcm.samples) - 1))
        self.resident = None
        self.now = 0.0
        self.now_run_base = 0.0
        self.now_run_cycles = 0
        # Calendar event core mirror: future arrivals as a heapq keyed
        # (arrival, submit_seq) — identical order to the Rust heap's
        # (arrival_s.to_bits(), seq) on the validated non-negative finite
        # domain. Scan mode (calendar=False) keeps everything in waiting.
        self.calendar = calendar
        self.arrivals = []
        self.submit_seq = 0
        self.waiting = []
        self.batch = []
        self.jobs = []
        self.prefill_turn = False
        self.finished = []
        self.swaps = 0
        self.hits = 0
        self.gaps_ms = []
        self.per_adapter = {}
        # Continuous paged-KV mode (mirrors ServerBuilder::continuous):
        # capacity derives from the ShardPlan KV share unless overridden.
        # The mirror steps continuous mode plainly (no fast-forward);
        # Rust's ff-with-pool path is gated bit-identical to stepwise in
        # tests/scheduling.rs, so every blessed counter agrees.
        self.pool = None
        if continuous:
            # Disagg: the paged pool lives on the decode pool's chips, so
            # its capacity inverts from the decode share only.
            cap_tokens = kv_pool_capacity_tokens(self.lm, tw_d)
            derived = cap_tokens // max(kv_page_tokens, 1)
            pages = derived if kv_pool_pages is None else kv_pool_pages
            assert pages <= derived and pages > 0, "mirror pool override"
            self.pool = KvPoolMirror(kv_page_tokens, pages)
        self.admit_seq = 0
        self.preemptions = 0
        self.preempted_tokens = 0
        # Disagg serving state: admitted requests whose prefill/migration
        # has not yet reached the decode pool, as (ready_s, Slot) in
        # admission order, plus the prefill pool's serialization horizon.
        self.pending = []
        self.prefill_free_s = 0.0
        # KV prefix cache (continuous mode only, like Rust: the cache
        # lives on the pool) + the prefill conservation ledger (u64).
        self.prefix = PrefixCacheMirror() if self.pool is not None else None
        self.preambles = {}
        self.prefix_admissions = 0
        self.prefix_cycles_saved = 0
        self.prefix_cycles_charged = 0
        self.prefix_rram_saved = 0

    def register_preamble(self, pid, blocks):
        assert blocks, "preamble has no blocks"
        if self.pool is not None:
            assert len(blocks) * self.pool.page_tokens <= self.ctx, \
                "preamble spans more than the serving template"
        self.preambles[pid] = list(blocks)

    # ---- cross-request KV prefix reuse (mirrors server.rs) ---------------

    def prefix_chain(self, req):
        if self.pool is None or self.prefix is None or req.preamble is None:
            return None
        chain = self.preambles.get(req.preamble)
        if chain is None or req.inp != self.ctx:
            return None
        block = self.blocks[0][0] if self.blocks else 0
        if block != self.pool.page_tokens \
                or len(chain) * self.pool.page_tokens > req.inp:
            return None
        return chain

    def admission_page_need(self, req):
        chain = self.prefix_chain(req)
        if chain is not None:
            _, misses = self.prefix.probe(chain)
            shared = len(chain) * self.pool.page_tokens
            return misses + self.pool.pages_for(req.inp - shared)
        return self.pool.pages_for(req.inp)

    def intern_prefix(self, req):
        chain = self.prefix_chain(req)
        if chain is None:
            return 0, 0
        hits = self.prefix.intern(chain, self.pool)
        l = self.n_layers
        self.prefix_admissions += 1
        self.prefix_cycles_saved += sum(self.block_cycles[:hits]) * l
        self.prefix_cycles_charged += sum(self.block_cycles[hits:]) * l
        self.prefix_rram_saved += sum(self.block_rram[:hits]) * l
        return hits, len(chain) * self.pool.page_tokens

    def release_prefix(self, req, shared_tokens):
        if shared_tokens == 0:
            return
        self.prefix.release(self.preambles[req.preamble], self.pool)

    def set_clock(self, t):
        self.now = t
        self.now_run_base = t
        self.now_run_cycles = 0

    def advance_decode_clock(self, cycles):
        self.now_run_cycles += cycles
        self.now = self.now_run_base + float(self.now_run_cycles) * CYCLE_S

    def submit(self, req):
        seq = self.submit_seq
        self.submit_seq += 1
        if self.calendar and req.arrival > self.now:
            heapq.heappush(self.arrivals, (req.arrival, seq, req))
            return
        pos = 0
        while pos < len(self.waiting) and self.waiting[pos].arrival <= req.arrival:
            pos += 1
        self.waiting.insert(pos, req)

    def sync_arrivals(self):
        # Calendar mode: pops come out in (arrival, seq) order, so the
        # arrived list stays exactly scan mode's sorted prefix.
        while self.arrivals and self.arrivals[0][0] <= self.now:
            req = heapq.heappop(self.arrivals)[2]
            pos = 0
            while pos < len(self.waiting) \
                    and self.waiting[pos].arrival <= req.arrival:
                pos += 1
            self.waiting.insert(pos, req)

    def arrived_count(self):
        if self.calendar:
            return len(self.waiting)
        arrived = 0
        while arrived < len(self.waiting) \
                and self.waiting[arrived].arrival <= self.now:
            arrived += 1
        return arrived

    def next_arrival_after_now(self):
        if self.calendar:
            return self.arrivals[0][0] if self.arrivals else None
        for r in self.waiting:
            if r.arrival > self.now:
                return r.arrival
        return None

    def active_adapter(self):
        if self.batch:
            return self.batch[0].req.adapter
        if self.jobs:
            return self.jobs[0].req.adapter
        if self.pending:
            return self.pending[0][1].req.adapter
        return None

    def chunk_schedule(self, inp, chunk, skip_blocks=0):
        nl = float(self.n_layers)
        if inp == self.ctx:
            blocks = self.blocks[skip_blocks:]
            block_tokens = max(self.blocks[0][0], 1) if self.blocks else 1
            per_chunk = max(-(-chunk // block_tokens), 1)
            cum = []
            cum_tokens = []
            k = 0
            while k < len(blocks):
                k1 = min(k + per_chunk, len(blocks))
                # plain left-to-right sum: mirrors Rust's iterator Sum order
                s = 0.0
                for _t, sec in blocks[:k1]:
                    s += sec
                cum.append(s * nl)
                cum_tokens.append(sum(t for t, _sec in blocks[:k1]))
                k = k1
            if not cum:
                # Fully interned prompt: one zero-cost chunk carries the
                # job (and any swap reprogramming) through the machinery.
                cum.append(0.0)
                cum_tokens.append(0)
            return cum, cum_tokens
        assert skip_blocks == 0, "off-template prompts never share"
        per_tok = 0.0
        for _t, sec in self.blocks:
            per_tok += sec
        per_tok = per_tok / float(self.ctx)
        n_chunks = max(-(-inp // chunk), 1)
        cum = [(per_tok * float(min(j * chunk, inp))) * nl
               for j in range(1, n_chunks + 1)]
        cum_tokens = [min(j * chunk, inp) for j in range(1, n_chunks + 1)]
        return cum, cum_tokens

    def monolithic_prefill_s(self, inp, hit_blocks=0):
        if inp == self.ctx:
            s = 0.0
            for _t, sec in self.blocks[hit_blocks:]:
                s += sec
        else:
            assert hit_blocks == 0, "off-template prompts never share"
            tot = 0.0
            for _t, sec in self.blocks:
                tot += sec
            s = tot / float(self.ctx) * float(inp)
        return s * float(self.n_layers)

    def admit(self, req):
        hits, shared = self.intern_prefix(req)
        seq = self.admit_seq
        self.admit_seq += 1
        if self.pool is not None:
            self.pool.alloc(seq, self.pool.pages_for(req.inp - shared))
        swap = self.resident != req.adapter
        self.resident = req.adapter
        if swap:
            self.swaps += 1
        else:
            self.hits += 1
        pa = self.per_adapter.setdefault(req.adapter, dict(served=0, swaps=0, hits=0))
        pa["swaps" if swap else "hits"] += 1
        if self.disagg:
            # Admission runs on the prefill pool: the event itself takes
            # zero decode-pool time (no batch stall, no clock advance) —
            # the overlap IS the disagg win. The prefill pool serializes
            # admissions (prefill_free_s); the finished prompt's unshared
            # KV then migrates pool-to-pool over one ChipMesh transfer
            # before the request may join the decode batch.
            pf_start = max(self.now, self.prefill_free_s)
            ttft = (self.reprog_s if swap else 0.0)
            ttft += self.monolithic_prefill_s(req.inp, hits)
            finish = pf_start + ttft
            self.prefill_free_s = finish
            migrate = chip_transfer_cycles(
                (req.inp - shared) * self.lm.kv_token_bytes * self.n_layers)
            migrate_s = float(migrate) * CYCLE_S
            self.pending.append(
                [finish + migrate_s,
                 Slot(req, 0, pf_start, swap, ttft + migrate_s,
                      admit_seq=seq, shared_tokens=shared)])
            return True
        if self.prefill_chunk is None:
            start = self.now
            ttft = (self.reprog_s if swap else 0.0)
            ttft += self.monolithic_prefill_s(req.inp, hits)
            for s in self.batch:
                s.stall_s += ttft
                s.pending_stall_s += ttft
            self.set_clock(self.now + ttft)
            self.batch.append(Slot(req, 0, start, swap, ttft, admit_seq=seq,
                                   shared_tokens=shared))
        else:
            cum, cum_tokens = self.chunk_schedule(req.inp, self.prefill_chunk,
                                                  hits)
            self.jobs.append(Job(req, swap, self.now,
                                 self.reprog_s if swap else 0.0, cum,
                                 admit_seq=seq, cum_tokens=cum_tokens,
                                 shared_tokens=shared))
        return True

    def chunk_step(self):
        job = self.jobs[0]
        old = self.now
        end = job.advance()
        new_now = end if end > old else old
        stall = new_now - old
        self.set_clock(new_now)
        for s in self.batch:
            s.stall_s += stall
            s.pending_stall_s += stall
        for j in self.jobs[1:]:
            j.external_s += stall
        if job.is_done():
            self.jobs.pop(0)
            self.batch.append(job.to_slot())

    # ---- continuous paged-KV pressure (mirrors resolve_kv_pressure) ------

    def resolve_kv_pressure(self):
        # Returns True iff eviction emptied the decode batch (the step's
        # event is the preemption itself). Victim order: youngest
        # admit_seq across jobs and slots, jobs win ties (jseq > sseq).
        if self.pool is None:
            return False
        preempted = False
        while True:
            short = 0
            for s in self.batch:
                # Page demand covers only the PRIVATE kv (shared prefix
                # pages are held by the cache's trie nodes).
                need = self.pool.pages_for(
                    s.req.inp - s.shared_tokens + s.generated + 1)
                short += max(need - self.pool.held_pages(s.admit_seq), 0)
            if short <= self.pool.free_pages():
                return preempted and not self.batch
            job = None
            for i, j in enumerate(self.jobs):
                if job is None or j.admit_seq >= job[1]:
                    job = (i, j.admit_seq)
            slot = None
            for i, s in enumerate(self.batch):
                if slot is None or s.admit_seq >= slot[1]:
                    slot = (i, s.admit_seq)
            pend = None
            for i, (_r, s) in enumerate(self.pending):
                if pend is None or s.admit_seq >= pend[1]:
                    pend = (i, s.admit_seq)
            if pend is not None and (job is None or pend[1] > job[1]) \
                    and (slot is None or pend[1] > slot[1]):
                self.preempt_pending(pend[0])
            elif job is not None and (slot is None or job[1] > slot[1]):
                self.preempt_job(job[0])
            else:
                self.preempt_slot(slot[0])
            preempted = True

    def requeue(self, req):
        pos = 0
        while pos < len(self.waiting) and self.waiting[pos].arrival <= req.arrival:
            pos += 1
        self.waiting.insert(pos, req)

    def preempt_job(self, ji):
        # The restart re-prefills the prompt KV the finished chunks wrote,
        # so those tokens are charged exactly like a slot's generated
        # tokens (the historic path silently dropped them and undercounted
        # preempted_tokens — the PR 8 bugfix).
        job = self.jobs.pop(ji)
        self.pool.release(job.admit_seq)
        self.preemptions += 1
        self.preempted_tokens += job.tokens_done()
        self.release_prefix(job.req, job.shared_tokens)
        self.requeue(job.req)

    def preempt_slot(self, si):
        s = self.batch.pop(si)
        self.pool.release(s.admit_seq)
        self.preemptions += 1
        self.preempted_tokens += s.generated
        self.release_prefix(s.req, s.shared_tokens)
        self.requeue(s.req)

    def preempt_pending(self, pi):
        # A pending (prefilled, not yet joined) victim discards the whole
        # unshared prompt KV it migrated — those tokens are the preemption
        # cost, exactly like a chunked job's finished-chunk tokens. The
        # prefill pool's horizon is NOT rolled back: the work was spent.
        _r, s = self.pending.pop(pi)
        self.pool.release(s.admit_seq)
        self.preemptions += 1
        self.preempted_tokens += s.req.inp - s.shared_tokens
        self.release_prefix(s.req, s.shared_tokens)
        self.requeue(s.req)

    def join_pending(self):
        # Ready pending requests join the decode batch in admission
        # order; the wait between ready and the joining event is
        # decode-side stall (charged like a prefill stall, so
        # total == ttft + stall + decode holds for disagg slots too).
        i = 0
        while i < len(self.pending):
            ready, slot = self.pending[i]
            if ready <= self.now:
                self.pending.pop(i)
                wait = self.now - ready
                slot.stall_s += wait
                slot.pending_stall_s += wait
                self.batch.append(slot)
            else:
                i += 1

    def decode_step(self):
        if self.resolve_kv_pressure():
            return
        if self.pool is not None:
            for s in self.batch:
                self.pool.grow_to(
                    s.admit_seq,
                    s.req.inp - s.shared_tokens + s.generated + 1)
        per = [self.lcm.eval_cycles(s.req.inp + s.generated) + self.ar_dec
               for s in self.batch]
        sc = step_cycles(per, self.n_layers, self.overhead)
        step_s = float(sc) * CYCLE_S
        self.advance_decode_clock(sc)
        for j in self.jobs:
            j.external_s += step_s
        done = []
        for s in self.batch:
            s.decode_cycles += sc
            s.generated += 1
            self.gaps_ms.append((step_s + s.pending_stall_s) * 1e3)
            s.pending_stall_s = 0.0
            if s.generated >= s.req.out:
                done.append(s)
        for s in done:
            self.batch.remove(s)
            self.retire(s)

    # ---- decode fast-forward (mirrors Server::fast_forward*) -------------

    def window_cycles(self, m):
        b = len(self.batch)
        ar = self.ar_dec
        max_kv = max(s.req.inp + s.generated for s in self.batch)
        total = 0
        s_max = 0
        for s in self.batch:
            kv = s.req.inp + s.generated
            si = self.lcm.sum_cycles_window(kv, m)
            total += si
            if kv == max_kv:
                s_max = si
        return total + m * b * ar + (self.n_layers - 1) * (s_max + m * ar) \
            + m * (b - 1) * self.overhead

    def steps_within(self, limit, strict, kmax):
        def ok(m):
            t = self.now_run_base \
                + float(self.now_run_cycles + self.window_cycles(m)) * CYCLE_S
            return t < limit if strict else t <= limit

        if ok(kmax):
            return kmax
        lo, hi = 0, kmax
        while hi - lo > 1:
            mid = lo + (hi - lo) // 2
            if ok(mid):
                lo = mid
            else:
                hi = mid
        return lo

    def fast_forward_window(self):
        # Continuous mode steps plainly in the mirror (Rust's pooled
        # fast-forward is gated bit-identical to stepwise in
        # tests/scheduling.rs, so plain stepping blesses the same values).
        if not self.fast_forward or not self.model_monotone \
                or self.jobs or not self.batch or self.pool is not None:
            return None
        k = min(s.req.out - s.generated for s in self.batch)
        cap = len(self.batch) + len(self.jobs) < self.max_batch
        if cap and (self.waiting or self.arrivals):
            arrived = self.arrived_count()
            if arrived > 0:
                # Side-effect-free probe (must not touch run-length state).
                pick = self.policy.peek(self.waiting[:arrived],
                                        self.active_adapter(), self.resident)
                if pick is not None:
                    return None
            nxt = self.next_arrival_after_now()
            if nxt is not None:
                k = min(k, self.steps_within(nxt, True, k) + 1)
        return k if k >= 2 else None

    def do_fast_forward(self, k):
        b = len(self.batch)
        kvs = [s.req.inp + s.generated for s in self.batch]
        imax = kvs.index(max(kvs))
        for step in range(k):
            per = [self.lcm.eval_cycles(kv + step) + self.ar_dec
                   for kv in kvs]
            sc = sum(per) + (self.n_layers - 1) * per[imax] \
                + (b - 1) * self.overhead
            step_s = float(sc) * CYCLE_S
            self.advance_decode_clock(sc)
            for s in self.batch:
                s.decode_cycles += sc
                s.generated += 1
                self.gaps_ms.append((step_s + s.pending_stall_s) * 1e3)
                s.pending_stall_s = 0.0
        done = [s for s in self.batch if s.generated >= s.req.out]
        for s in done:
            self.batch.remove(s)
            self.retire(s)
        self.prefill_turn = True

    def retire(self, s):
        if self.pool is not None:
            self.pool.release(s.admit_seq)
        self.release_prefix(s.req, s.shared_tokens)
        decode_s = float(s.decode_cycles) * CYCLE_S
        itl_ms = decode_s / float(s.req.out) * 1e3
        self.per_adapter[s.req.adapter]["served"] += 1
        self.finished.append(dict(
            id=s.req.id, adapter=s.req.adapter, swap=s.swap,
            arrival=s.req.arrival, start=s.start_s,
            queue=s.start_s - s.req.arrival, ttft=s.ttft_s, itl_ms=itl_ms,
            stall=s.stall_s, total=s.ttft_s + s.stall_s + decode_s,
            out=s.req.out))

    def step(self):
        self.sync_arrivals()
        if self.disagg:
            self.join_pending()
        cap = len(self.batch) + len(self.jobs) + len(self.pending) \
            < self.max_batch
        if cap and self.waiting:
            arrived = self.arrived_count()
            if arrived > 0:
                # Paged admission gate: side-effect-free peek first; a
                # blocked candidate must leave run-length state untouched.
                blocked = False
                if self.pool is not None:
                    i = self.policy.peek(self.waiting[:arrived],
                                         self.active_adapter(), self.resident)
                    if i is not None:
                        blocked = self.admission_page_need(self.waiting[i]) \
                            > self.pool.free_pages()
                if not blocked:
                    pick = self.policy.pick(self.waiting[:arrived],
                                            self.active_adapter(),
                                            self.resident)
                    if pick is None and not self.batch and not self.jobs \
                            and not self.pending \
                            and arrived == len(self.waiting) \
                            and not self.arrivals:
                        pick = 0
                    if pick is not None:
                        req = self.waiting.pop(pick)
                        self.admit(req)
                        return "admitted"
        if self.jobs and (self.prefill_turn or not self.batch):
            self.prefill_turn = False
            self.chunk_step()
            return "chunk"
        if self.batch:
            self.prefill_turn = True
            self.decode_step()
            return "decoded"
        nxt = self.next_arrival_after_now()
        if self.pending:
            ready = min(r for r, _s in self.pending)
            nxt = ready if nxt is None or ready < nxt else nxt
        if nxt is not None:
            self.set_clock(nxt)
            return "advanced"
        if self.waiting:
            raise RuntimeError("deadlock")
        return "idle"

    def drain(self):
        while True:
            self.sync_arrivals()
            k = self.fast_forward_window()
            if k is not None:
                self.do_fast_forward(k)
                continue
            if self.step() == "idle":
                break
        return self.finished


# ---------------------------------------------------------------------------
# trace::workload mirror (integer load stream only)
# ---------------------------------------------------------------------------

MASK64 = (1 << 64) - 1


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    """util::Rng (SplitMix64-seeded xoshiro256**), bit-exact."""

    def __init__(self, seed):
        s = []
        sm = seed & MASK64
        for _ in range(4):
            sm, z = _splitmix64(sm)
            s.append(z)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def range(self, lo, hi):
        return lo + self.next_u64() % (hi - lo)


LOAD_STREAM_SALT = 0xA5A55A5AC3C33C3C


def workload_load_checksums(seed, n, adapters, max_input, max_output):
    """trace::workload::load_checksum over a generated spec: the
    (adapter, input, output) integer sums. The load stream draws exactly
    4 values per request from its own salted RNG — no libm, no arrival
    coupling — so these are bit-identical across languages and arrival
    laws (the Zipf pick is basic IEEE +,*,/ and compares, exact-rounded
    everywhere)."""
    load = Rng(seed ^ LOAD_STREAM_SALT)
    weights = [1.0 / (k + 1.0) for k in range(adapters)]
    total_weight = 0.0
    for w in weights:  # plain left-to-right sum, as Rust's iter().sum()
        total_weight += w
    a_sum = i_sum = o_sum = 0
    for _ in range(n):
        pick = load.f64() * total_weight
        acc = 0.0
        adapter = adapters - 1
        for k, w in enumerate(weights):
            acc += w
            if pick < acc:
                adapter = k
                break
        base = max(max_input, 16) >> load.range(0, 3)
        jitter = load.range(0, base // 8 + 1)
        inp = max(base - jitter, 16)
        out = 4 + load.range(0, max(max_output, 1))
        a_sum += adapter
        i_sum += inp
        o_sum += out
    return a_sum, i_sum, o_sum


def workload_prefix_checksums(seed, n, adapters, max_input, max_output,
                              share=0.5, preambles=4):
    """WorkloadKind::Prefix load-stream checksums: same 4-draw contract
    (adapter pick, share coin, Zipf preamble pick, output draw), prompts
    pinned at max_input. Returns (adapter_sum, input_sum, output_sum,
    preamble_checksum) where the last mirrors trace::workload::
    preamble_checksum (sum of preamble id + 1 over shared requests)."""
    load = Rng(seed ^ LOAD_STREAM_SALT)
    weights = [1.0 / (k + 1.0) for k in range(adapters)]
    total_weight = 0.0
    for w in weights:
        total_weight += w
    pre_weights = [1.0 / (k + 1.0) for k in range(max(preambles, 1))]
    pre_total = 0.0
    for w in pre_weights:
        pre_total += w
    a_sum = i_sum = o_sum = p_sum = 0
    for _ in range(n):
        pick = load.f64() * total_weight
        acc = 0.0
        adapter = adapters - 1
        for k, w in enumerate(weights):
            acc += w
            if pick < acc:
                adapter = k
                break
        shared = load.f64() < share
        ppick = load.f64() * pre_total  # drawn even when the coin misses
        pacc = 0.0
        p = preambles - 1
        for k, w in enumerate(pre_weights):
            pacc += w
            if ppick < pacc:
                p = k
                break
        out = 4 + load.range(0, max(max_output, 1))
        a_sum += adapter
        i_sum += max_input
        o_sum += out
        if shared:
            p_sum += p + 1
    return a_sum, i_sum, o_sum, p_sum


def mix64(x):
    """splitmix64 finalizer (the preamble-library block content hash)."""
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & MASK64
    return x ^ (x >> 31)


def preamble_library_chains(preambles, max_blocks):
    """trace::workload::PreambleLibrary::new — chain p keeps
    1 + p % max_blocks blocks; block d hashes the preamble-index group
    p >> (max_blocks - 1 - d): coarse at the root, unique at the leaves,
    prefix-closed by construction."""
    assert max_blocks >= 1
    chains = []
    for p in range(preambles):
        depth = 1 + p % max_blocks
        chains.append([mix64(((d << 32) | (p >> (max_blocks - 1 - d)))
                             & MASK64)
                       for d in range(depth)])
    return chains


# ---------------------------------------------------------------------------
# heterogeneous batched engine mirror (total_cycles only)
# ---------------------------------------------------------------------------

def hetero_cycles(model, targets, prompts, out, srpg=True, overhead=64):
    """Mirror of Simulator::run_hetero_batched's total_cycles at one chip
    with the lm_head off (the paper defaults): all-reduce terms vanish,
    so only the per-slot prefill block decomposition and the closed-form
    decode bound survive — pure u64 arithmetic end to end."""
    m = MODELS[model]
    lm = map_model(model, targets)
    b = len(prompts)
    n_groups = m["layers"]
    reprog = program_cost(reprogram_program(lm))

    layer_cycles_list = []
    for p in prompts:
        block = min(128, p)
        n_blocks = -(-p // block)
        cycles = 0
        for bi in range(n_blocks):
            this_block = p - bi * block if bi + 1 == n_blocks else block
            kvv = bi * block + this_block // 2
            cycles += program_cost(
                prefill_program(model, targets, lm, this_block,
                                max(kvv, 1))).cycles
        layer_cycles_list.append(cycles)
    # SRPG overlaps only slot 0's layer wave (the first admission).
    layer0 = layer_cycles_list[0]
    group_start = [l * layer0 for l in range(n_groups)]
    prefill_makespan = sum(layer_cycles_list) * n_groups
    ttft_penalty, stalls = srpg_plan(n_groups, reprog.cycles, group_start,
                                     srpg)
    ttft_cycles = ttft_penalty + prefill_makespan + stalls

    if out == 0:
        return ttft_cycles
    lcm = LayerCostModel(model, targets, lm)
    compute_sum = 0
    for p in prompts:
        compute_sum += lcm.sum_cycles_window(p, out)
    sc_max = lcm.sum_cycles_window(max(prompts), out)
    decode_total = compute_sum + (n_groups - 1) * sc_max \
        + out * (b - 1) * overhead
    return ttft_cycles + decode_total


# ---------------------------------------------------------------------------
# disaggregated pool tier mirror (Simulator::run_disagg_batched)
# ---------------------------------------------------------------------------

def chip_transfer_cycles(bytes_):
    """noc::ChipMesh::transfer_cycles — one point-to-point pool/stage link
    hop plus the streamed volume. Zero only at zero bytes."""
    if bytes_ == 0:
        return 0
    return CHIP_HOP_CYCLES + math.ceil(float(bytes_) / CHIP_LINK_BPC)


def pool_stage_layers(n_layers, stages):
    """mapping::PoolPlan::stage_layers (contiguous split_even ranges)."""
    return split_even(n_layers, max(stages, 1))


def run_disagg(model, targets, ctx, batch=1, prefill_chips=None,
               decode_chips=None, stages=1, srpg=True, overhead=64,
               n_chips=1, out_tokens=None):
    """Op-for-op mirror of Simulator::run_disagg_batched.

    prefill_chips/decode_chips None = a unified pool of n_chips (the
    degenerate plan); set together they define the split and n_chips is
    their sum. Returns (report, info): report carries exactly the
    run_batched dict keys (the unified single-stage case must compare
    EQUAL to run_batched — every float is produced by the same operation
    sequence), info carries the disagg-only observables (ready staircase,
    migration cost, token-slot conservation counters)."""
    m = MODELS[model]
    lm = map_model(model, targets)
    b = max(batch, 1)
    is_disagg = prefill_chips is not None
    if is_disagg:
        assert decode_chips is not None and prefill_chips >= 1 \
            and decode_chips >= 1, "pools set together, >= 1 chip each"
        nc = prefill_chips + decode_chips
        pool_p, pool_d = prefill_chips, decode_chips
    else:
        nc = max(n_chips, 1)
        pool_p = pool_d = nc
    s = max(stages, 1)
    assert pool_p % s == 0 and pool_d % s == 0, "stages must divide pools"
    n_groups = m["layers"]
    assert s <= n_groups, "more stages than layers"
    stage_layers = pool_stage_layers(n_groups, s)
    tw_p = max(pool_p // s, 1)
    tw_d = max(pool_d // s, 1)
    hidden = m["hidden"]
    ledger = Ledger()
    cts_per_group = lm.n_cts
    total_cts = n_groups * cts_per_group * nc

    # ---- prefill: block decomposition at the prefill stage width --------
    reprog = program_cost(reprogram_program(lm))
    block = min(128, max(ctx, 1))
    n_blocks = -(-ctx // block)
    stage_compute = 0
    lpc = 0  # per-layer prefill cycles (compute + all-reduce)
    prefill_events = Cost()
    prefill_ar_link = 0
    for bi in range(n_blocks):
        this_block = ctx - bi * block if bi + 1 == n_blocks else block
        kvv = bi * block + this_block // 2
        prog = prefill_program(model, targets, lm, this_block, max(kvv, 1))
        c = program_cost(prog)
        compute = c.cycles if tw_p == 1 else program_cost(
            shard_program_slice(prog, 0, tw_p)).cycles
        lpc += compute + layer_all_reduce_cycles(tw_p, hidden, this_block)
        stage_compute += compute
        prefill_ar_link += layer_all_reduce_link_bytes(tw_p, hidden, this_block)
        prefill_events._merge_events(c)
    group_start = [l * lpc for l in range(n_groups)]
    ttft_penalty, stalls = srpg_plan(n_groups, reprog.cycles, group_start, srpg)

    # ---- prefill pipeline packing ---------------------------------------
    stage_max = max(lj * lpc for lj in stage_layers)
    act_bytes = hidden * 4 * ctx
    h_p = chip_transfer_cycles(act_bytes) if s > 1 else 0
    fill = n_groups * lpc + (s - 1) * h_p
    m_p = max(stage_max, h_p)

    def finish_of(r):
        return ttft_penalty + stalls + fill + r * m_p

    prefill_span = finish_of(b - 1)

    # ---- KV migration (pool-to-pool) ------------------------------------
    migrate_bytes_per_req = ctx * lm.kv_token_bytes * n_groups
    migrate_cycles = chip_transfer_cycles(migrate_bytes_per_req) \
        if is_disagg else 0
    ready = [finish_of(r) + migrate_cycles if is_disagg else prefill_span
             for r in range(b)]
    ready_last = ready[b - 1]

    # ---- prefill energy (same post order as run_batched) ----------------
    ledger.post_cost_events(prefill_events, scale=n_groups * b)
    ledger.post_sram_writes(reprog.reprog_bytes * n_groups)
    if tw_p > 1:
        ledger.net += float(prefill_ar_link * (n_groups * b) * 4) \
            * CAL["hop_energy_pj_per_byte"] * 1e-12
    if s > 1:
        ledger.net += float(act_bytes * (s - 1) * b * 4) \
            * CAL["hop_energy_pj_per_byte"] * 1e-12
    if is_disagg:
        ledger.net += float(migrate_bytes_per_req * b * 4) \
            * CAL["hop_energy_pj_per_byte"] * 1e-12
    active_ct = float(stage_compute) * float(n_groups * cts_per_group * b * tw_p)
    total_ct = float(prefill_span) * float(total_cts)
    reprog_ct = float(reprog.cycles * n_groups) * float(cts_per_group) * float(nc)
    idle_ct = max(total_ct - active_ct - reprog_ct, 0.0)
    idle_state = "gated" if srpg else "idle_ungated"
    ledger.post_state("active", active_ct, 1)
    ledger.post_state(idle_state, idle_ct, 1)
    ledger.post_state("reprogramming", reprog_ct, 1)

    # ---- decode staircase ------------------------------------------------
    model_lcm = LayerCostModel(model, targets, lm)
    shard_lcm = model_lcm if tw_d == 1 \
        else LayerCostModel(model, targets, lm, tw_d)
    ar_dec = layer_all_reduce_cycles(tw_d, hidden, 1)
    ar_dec_link = layer_all_reduce_link_bytes(tw_d, hidden, 1)
    out = ctx if out_tokens is None else out_tokens
    tok_act_bytes = hidden * 4

    t_clock = min(ready)
    done = [0] * b
    decode_events = Cost()
    decode_compute_sum = 0
    token_slots = 0
    handoff_bytes = 0
    if out == 0:
        t_clock = ready_last
    while any(d < out for d in done):
        present = [r for r in range(b) if done[r] < out and ready[r] <= t_clock]
        if not present:
            t_clock = min(ready[r] for r in range(b) if done[r] < out)
            continue
        costs = []
        for r in present:
            kv = ctx + done[r]
            ev = lerped_cost(model_lcm, kv)
            compute = ev.cycles if tw_d == 1 else shard_lcm.eval_cycles(kv)
            costs.append(compute + ar_dec)
            decode_events._merge_events(ev)
            decode_compute_sum += compute
        k = len(present)
        step_handoff_bytes = tok_act_bytes * k * (s - 1) if s > 1 else 0
        handoff = chip_transfer_cycles(tok_act_bytes * k) * (s - 1) \
            if s > 1 else 0
        step = step_cycles(costs, n_groups, overhead) + handoff
        t_clock += step
        token_slots += k
        handoff_bytes += step_handoff_bytes
        for r in present:
            done[r] += 1
    total_cycles = max(t_clock, ready_last)
    decode_span = total_cycles - ready_last

    # ---- decode energy (same post order) --------------------------------
    if out > 0:
        ledger.post_cost_events(decode_events, scale=n_groups)
        if tw_d > 1:
            ledger.net += float(ar_dec_link * token_slots * n_groups * 4) \
                * CAL["hop_energy_pj_per_byte"] * 1e-12
        if s > 1:
            ledger.net += float(handoff_bytes * 4) \
                * CAL["hop_energy_pj_per_byte"] * 1e-12
        if b == 1 and nc == 1:
            active = float(decode_span) * float(cts_per_group)
            idle = float(decode_span) * float((n_groups - 1) * cts_per_group)
        else:
            active_int = (n_groups * tw_d) * decode_compute_sum * cts_per_group
            total_int = decode_span * (n_groups * cts_per_group * nc)
            active = float(active_int)
            idle = float(max(total_int - active_int, 0))
        ledger.post_state("active", active, 1)
        ledger.post_state(idle_state, idle, 1)

    # ---- report ----------------------------------------------------------
    ledger.span_cycles = total_cycles
    ttft_s = float(ready_last) * CYCLE_S
    itl_ms = float(decode_span) / float(out) * CYCLE_S * 1e3 if out else 0.0
    total_s = ttft_s + float(decode_span) * CYCLE_S
    tokens = float((ctx + out) * b)
    tput = tokens / total_s
    power = ledger.avg_power_w()
    report = dict(ttft_s=ttft_s, itl_ms=itl_ms, throughput=tput, power=power,
                  eff=tput / max(power, 1e-12), energy=ledger.total_j(),
                  cycles=total_cycles)
    info = dict(ready=ready, prefill_span=prefill_span,
                migrate_cycles=migrate_cycles,
                migrate_bytes=migrate_bytes_per_req,
                token_slots=token_slots, lpc=lpc,
                stage_compute=stage_compute, decode_span=decode_span,
                prefill_events=prefill_events)
    return report, info


# ---------------------------------------------------------------------------
# proxy baseline + checks
# ---------------------------------------------------------------------------

# The 12 registry counters in Rust declaration order (the field order of
# RegistryStats and of every pass object in BENCH_sweep.json).
REGISTRY_FIELDS = [
    "mapping_hits", "mapping_builds",
    "layer_model_hits", "layer_model_builds",
    "prefill_hits", "prefill_builds",
    "reprog_hits", "reprog_builds",
    "programs_generated",
    "window_hits", "window_inserts", "window_full_skips",
]

BUILD_FIELDS = ["mapping_builds", "layer_model_builds", "prefill_builds",
                "reprog_builds"]


def sweepcache_replay():
    """Structural replay of the Rust sweep-costing-cache counters on the
    bench's 12-point grid (1B, LoRA on Q only; ctx {256, 512, 1024} x
    batch {1, 4} x chips {1, 2}).

    The registry keys every cached artifact on the structural class
    (model, LoRA set, system, calibration — plus per-kind fields), never
    on the swept ctx/batch axes, so hit/build counts are a pure function
    of the grid shape and the engine's lookup pattern:

      * one ModelMapping lookup per point;
      * one width-1 LayerCostModel lookup per point, plus one
        width-`chips` lookup when sharded (each build generates the 10
        decode-sample programs);
      * one prefill block-cost lookup per 128-token block, keyed
        (width, block, mid-block causal kv) — a miss generates one
        prefill program;
      * one reprogram-template lookup per point (a miss generates one
        program);
      * the decode window memo: one `sum_window` fold per point on the
        width-1 model, keyed (kv0 = ctx, n = out = ctx), plus one
        `sum_cycles_window` fold on the width-`chips` model when
        sharded.

    Cache state persists across passes, so pass 1 is the cold run and
    passes 2-3 are incremental reruns. Warm counters are worker-width
    independent (every lookup hits an already-present key), which is why
    the Rust bench pins warm_jobs1 == warm_jobs4 bit-for-bit.
    """
    grid = [(ctx, batch, chips)
            for ctx in (256, 512, 1024)
            for batch in (1, 4)
            for chips in (1, 2)]
    mappings, models, prefills, reprogs = set(), set(), set(), set()
    windows = {}
    passes = []
    for _ in range(3):
        st = {k: 0 for k in REGISTRY_FIELDS}

        def touch(cache, key, kind, n_programs=0):
            if key in cache:
                st[kind + "_hits"] += 1
            else:
                cache.add(key)
                st[kind + "_builds"] += 1
                st["programs_generated"] += n_programs

        for (ctx, _batch, chips) in grid:
            touch(mappings, "1b-q", "mapping")
            touch(models, ("1b-q", 1), "layer_model", 10)
            if chips > 1:
                touch(models, ("1b-q", chips), "layer_model", 10)
            touch(reprogs, "1b-q", "reprog", 1)
            block = 128
            for b in range(ctx // block):
                kv = b * block + block // 2
                touch(prefills, ("1b-q", chips, block, kv), "prefill", 1)
            folds = [("events", 1)]
            if chips > 1:
                folds.append(("cycles", chips))
            for fold in folds:
                memo = windows.setdefault(fold, set())
                if (ctx, ctx) in memo:
                    st["window_hits"] += 1
                else:
                    memo.add((ctx, ctx))
                    st["window_inserts"] += 1
        passes.append(st)
    return grid, passes


def sweepcache_proxies():
    """The seven sweepcache_* entries of sim_proxy.txt, from the replay."""
    _, (cold, warm1, warm4) = sweepcache_replay()
    return {
        "sweepcache_cold_mapping_builds": cold["mapping_builds"],
        "sweepcache_cold_model_builds": cold["layer_model_builds"],
        "sweepcache_cold_prefill_builds": cold["prefill_builds"],
        "sweepcache_cold_program_gens": cold["programs_generated"],
        "sweepcache_cold_reprog_builds": cold["reprog_builds"],
        "sweepcache_warm_program_gens":
            warm1["programs_generated"] + warm4["programs_generated"],
        "sweepcache_warm_total_builds":
            sum(warm1[k] + warm4[k] for k in BUILD_FIELDS),
    }


def sweepcache_json():
    """BENCH_sweep.json, byte-identical to the Rust bench's emitter."""
    _, passes = sweepcache_replay()
    out = [
        '{',
        '  "schema": "primal-sweep-cache-v1",',
        '  "grid": {',
        '    "model": "1b",',
        '    "lora_targets": "q",',
        '    "ctx": [256, 512, 1024],',
        '    "batch": [1, 4],',
        '    "chips": [1, 2],',
        '    "points": 12',
        '  },',
        '  "passes": {',
    ]
    names = ("cold_jobs1", "warm_jobs1", "warm_jobs4")
    for i, (name, st) in enumerate(zip(names, passes)):
        out.append(f'    "{name}": {{')
        for j, k in enumerate(REGISTRY_FIELDS):
            comma = "," if j + 1 < len(REGISTRY_FIELDS) else ""
            out.append(f'      "{k}": {st[k]}{comma}')
        out.append('    }' + ("," if i + 1 < len(names) else ""))
    out.extend(['  }', '}'])
    return "\n".join(out) + "\n"


def proxies_13b():
    targets = ["Q", "V"]
    lm = map_model("13b", targets)
    d2048 = program_cost(decode_program("13b", targets, lm, 2048))
    d0 = program_cost(decode_program("13b", targets, lm, 0))
    pre = program_cost(prefill_program("13b", targets, lm, 128, 1024))
    rep = program_cost(reprogram_program(lm))
    # Fast-path proxies: the [2048, 4096) decode sweep summed with the
    # retained PER-TOKEN loop (the blessing source — the Rust bench
    # recomputes these with the closed form, so the committed equality IS
    # the fast-vs-reference gate), plus the closed-form 13B end-to-end
    # cycle count (cross-checked against the per-token engine below).
    lcm = LayerCostModel("13b", targets, lm)
    sweep = Cost()
    for kv in range(2048, 4096):
        ev = lerped_cost(lcm, kv)
        sweep.cycles += ev.cycles
        sweep._merge_events(ev)
    e2e = run_batched("13b", targets, 2048, batch=1, closed_form=True)
    # Continuous paged-KV backlog (the bench's engineered 5-page scenario).
    # Every blessed counter is a step-sequence integer, so the mirror's
    # plain stepping blesses the fast-forwarding Rust run too — the
    # ff/stepwise bit-identity is gated in tests/scheduling.rs.
    cont = Server("1b", ["Q", "V"], 128, max_batch=4, policy="fcfs",
                  continuous=True, kv_pool_pages=5, fast_forward=False)
    for i in range(8):
        cont.submit(Req(i, 0, 128, 140, 0.0))
    assert len(cont.drain()) == 8, "continuous backlog lost requests"
    # Prefix-reuse ledger on the 8-way shared-preamble wave (the bench's
    # scenario): one cold intern, seven hits, exact u64 cycle/RRAM credit.
    pfx = Server("1b", ["Q", "V"], 256, max_batch=8, policy="fcfs",
                 continuous=True, fast_forward=False)
    pfx.register_preamble(0, [0xBEEF])
    for i in range(8):
        pfx.submit(Req(i, 0, 256, 16, 0.0, preamble=0))
    assert len(pfx.drain()) == 8, "prefix wave lost requests"
    template = sum(pfx.block_cycles) * pfx.n_layers
    assert pfx.prefix_cycles_saved + pfx.prefix_cycles_charged \
        == pfx.prefix_admissions * template, "prefill FLOP conservation"
    assert pfx.prefix.interns == pfx.prefix.releases \
        and pfx.prefix.live_nodes() == 0, "prefix refcount conservation"
    assert pfx.pool.allocs == pfx.pool.frees and pfx.pool.used == 0, \
        "prefix wave leaked pages"
    # Disaggregated pools (the Table II --disagg winning cell): 13B
    # ctx 2048, out 256, an 8-request FCFS backlog at max_batch 4 —
    # symmetric 4-chip continuous serving vs the 2p+2d split at equal
    # total chips. The split wins on drain time because admissions
    # prefill on the prefill pool while the decode pool keeps stepping
    # (monolithic admissions stall the whole symmetric batch). Drain
    # witnesses are truncated-nanosecond integers; the Rust bench
    # recomputes both serves and the committed equality is the gate.
    def disagg_cell(split):
        kw = dict(max_batch=4, policy="fcfs", continuous=True,
                  fast_forward=False)
        if split is None:
            s = Server("13b", targets, 2048, n_chips=4, **kw)
        else:
            s = Server("13b", targets, 2048, prefill_chips=split[0],
                       decode_chips=split[1], **kw)
        for i in range(8):
            s.submit(Req(i, 0, 2048, 256, 0.0))
        assert len(s.drain()) == 8, "disagg cell lost requests"
        return s
    dsym = disagg_cell(None)
    dsp = disagg_cell((2, 2))
    assert dsp.now < dsym.now, \
        "2p+2d must beat symmetric 4-chip serving on the prefill-heavy mix"
    assert dsym.preemptions == 0 and dsp.preemptions == 0, \
        "winning cell must be preemption-free on both sides"
    assert dsp.pool.allocs == dsp.pool.frees and dsp.pool.used == 0, \
        "disagg serve leaked pages"
    # Engine-side integer witnesses: the closed-batch disagg staircase
    # (2p+2d) and its 2-stage pipeline-packed variant.
    deng, _ = run_disagg("13b", targets, 2048, batch=4, prefill_chips=2,
                         decode_chips=2, out_tokens=256)
    dpipe, _ = run_disagg("13b", targets, 2048, batch=4, prefill_chips=2,
                          decode_chips=2, stages=2, out_tokens=256)
    hetero13b = hetero_cycles("13b", targets, [512, 1024, 2048], 2048)
    wl_a, wl_i, wl_o = workload_load_checksums(42, 4096, 8, 512, 32)
    wp_a, _, wp_o, wp_pre = workload_prefix_checksums(42, 4096, 8, 512, 32)
    assert (wp_a, wp_o) == (wl_a, wl_o), \
        "prefix mix shifted the adapter/output draw positions"
    return {
        "cont_page_allocs": cont.pool.allocs,
        "cont_page_frees": cont.pool.frees,
        "cont_peak_pages": cont.pool.peak,
        "cont_preemptions": cont.preemptions,
        "decode0_cycles": d0.cycles,
        "decode2048_cycles": d2048.cycles,
        "decode2048_dmac_macs": d2048.dmac_macs,
        "decode2048_net_byte_hops": d2048.net_byte_hops,
        "decode2048_rram_passes": d2048.rram_passes,
        "decode2048_softmax_elems": d2048.softmax_elems,
        "decode2048_sram_passes": d2048.sram_passes,
        "decode_sweep_cycles": sweep.cycles,
        "disagg13b_2p2d_drain_ns": int(dsp.now * 1e9),
        "disagg13b_2p2d_page_allocs": dsp.pool.allocs,
        "disagg13b_2p2d_peak_pages": dsp.pool.peak,
        "disagg13b_e2e_cycles": deng["cycles"],
        "disagg13b_pipe2_cycles": dpipe["cycles"],
        "disagg13b_sym4_drain_ns": int(dsym.now * 1e9),
        "decode_sweep_dmac_macs": sweep.dmac_macs,
        "decode_sweep_net_byte_hops": sweep.net_byte_hops,
        "decode_sweep_rram_passes": sweep.rram_passes,
        "e2e13b_total_cycles": e2e["cycles"],
        "hetero13b_total_cycles": hetero13b,
        "prefill128_kv1024_cycles": pre.cycles,
        "prefix_cycles_saved": pfx.prefix_cycles_saved,
        "prefix_hit_blocks": pfx.prefix.hit_blocks,
        "prefix_miss_blocks": pfx.prefix.miss_blocks,
        "prefix_rram_saved": pfx.prefix_rram_saved,
        "reprogram_cycles": rep.cycles,
        "workload_adapter_sum": wl_a,
        "workload_input_sum": wl_i,
        "workload_output_sum": wl_o,
        "workload_preamble_sum": wp_pre,
    }, lm


def main():
    check = "--check" in sys.argv
    if "--bench-sweep-json" in sys.argv:
        # Emit BENCH_sweep.json for blessing (byte-identical to the Rust
        # bench's emitter and to the committed baseline).
        sys.stdout.write(sweepcache_json())
        return

    px, lm13 = proxies_13b()
    px.update(sweepcache_proxies())
    print(f"# 13B mapping: {lm13.n_cts} CTs/layer")
    print("# instruction-count proxies (13B Q+V 2048 point):")
    for k in sorted(px):
        print(f"{k} {px[k]}")

    if not check:
        return

    failures = []

    def gate(name, cond, detail=""):
        print(f"  {'PASS' if cond else 'FAIL'}  {name} {detail}")
        if not cond:
            failures.append(name)

    # ---- fast paths: closed-form decode == per-token reference -----------
    print("\n== closed-form decode vs per-token reference (bit equality) ==")
    import time
    lerp_ok = True
    for mdl in ("1b", "8b", "13b"):
        lmx = map_model(mdl, ["Q", "V"])
        lcm = LayerCostModel(mdl, ["Q", "V"], lmx)
        for kv in range(0, 9001, 13):
            a = lerped_cost(lcm, kv)
            bb = lerped_cost_f64(lcm, kv)
            if a != bb:
                lerp_ok = False
                print(f"  integer/f64 lerp mismatch at {mdl} kv={kv}")
                break
    gate("integer lerp == historical f64 lerp (all fields, kv sweep)", lerp_ok)

    sum_ok = True
    lcm13 = LayerCostModel("13b", ["Q", "V"], map_model("13b", ["Q", "V"]))
    for (kv0, n) in ((0, 300), (100, 100), (1024, 2048), (2048, 2048),
                     (4000, 200), (8000, 600), (511, 2), (777, 0)):
        fast = lcm13.sum_window(kv0, n)
        slow = Cost()
        for kv in range(kv0, kv0 + n):
            ev = lerped_cost(lcm13, kv)
            slow.cycles += ev.cycles
            slow._merge_events(ev)
        sum_ok &= fast == slow
    gate("sum_window == per-token sweep (floor-sum exactness)", sum_ok)

    eng_ok = True
    for mdl in ("1b", "8b", "13b"):
        for ctx in (1024, 2048):
            for batch, chips in ((1, 1), (4, 1), (1, 2), (4, 4)):
                if not config_validate_kv(mdl, ["Q", "V"], ctx, batch, chips):
                    continue
                fast = run_batched(mdl, ["Q", "V"], ctx, batch=batch,
                                   n_chips=chips, closed_form=True)
                slow = run_batched(mdl, ["Q", "V"], ctx, batch=batch,
                                   n_chips=chips, closed_form=False)
                if fast != slow:
                    eng_ok = False
                    print(f"  engine mismatch {mdl}/{ctx} b{batch} c{chips}")
    gate("closed-form engine bit-matches per-token on grid x batch x chips",
         eng_ok)
    srpg_ff_ok = True
    for srpg_flag in (True, False):
        fa = run_batched("1b", ["Q", "V"], 777, batch=4, srpg=srpg_flag,
                         closed_form=True, out_tokens=333)
        sl = run_batched("1b", ["Q", "V"], 777, batch=4, srpg=srpg_flag,
                         closed_form=False, out_tokens=333)
        srpg_ff_ok &= fa == sl
    gate("closed form bit-matches on odd lengths x srpg", srpg_ff_ok)

    t0 = time.perf_counter()
    ref13 = run_batched("13b", ["Q", "V"], 2048, closed_form=False)
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast13 = run_batched("13b", ["Q", "V"], 2048, closed_form=True)
    t_fast = time.perf_counter() - t0
    gate("13B 2048/2048 closed form == per-token", fast13 == ref13)
    print(f"  mirror decode-path wall clock: per-token {t_ref*1e3:.1f} ms vs "
          f"closed-form {t_fast*1e3:.1f} ms "
          f"({t_ref/max(t_fast, 1e-9):.1f}x; both include prefill costing)")

    # ---- coordinator fast-forward == stepwise ----------------------------
    print("\n== coordinator decode fast-forward (bit equality) ==")
    ff_ok = True
    ff_traces = [
        [(i, i % 3, 64 + 37 * i, 5 + 11 * i, 0.002 * i) for i in range(9)],
        [(i, 0, 256, 40, 0.0) for i in range(6)],
        [(0, 0, 256, 200, 0.0), (1, 0, 128, 150, 0.001),
         (2, 0, 300, 120, 0.002), (3, 0, 64, 260, 0.003)],
    ]
    for policy in ("fcfs", "affinity", "sjf"):
        for batch in (1, 4):
            for chunk in (None, 128):
                for chips in (1, 2):
                    for trace in ff_traces:
                        runs = []
                        for ff in (True, False):
                            s = Server("1b", ["Q", "V"], 256, max_batch=batch,
                                       policy=policy, prefill_chunk=chunk,
                                       n_chips=chips, fast_forward=ff)
                            for r in trace:
                                s.submit(Req(*r))
                            res = s.drain()
                            runs.append((res, s.now, s.gaps_ms, s.swaps, s.hits))
                        if runs[0] != runs[1]:
                            ff_ok = False
                            print(f"  ff mismatch {policy}/b{batch}/"
                                  f"chunk{chunk}/c{chips}")
    gate("fast-forward == stepwise (results, clock, gaps, swaps)", ff_ok)

    # The affinity starvation bound is the stateful-policy blind spot: a
    # discarded admission probe must NOT advance the run counter, so the
    # bound fires at the same admissions with and without fast-forward.
    mrl_ok = True
    mrl_trace = [(i, 0, 256, 30, 0.0) for i in range(6)] \
        + [(6, 1, 256, 30, 0.0), (7, 1, 256, 30, 0.05)]
    for batch in (1, 4):
        for mrl in (1, 2, 3):
            runs = []
            for ff in (True, False):
                s = Server("1b", ["Q", "V"], 256, max_batch=batch,
                           policy="affinity", max_run_len=mrl, fast_forward=ff)
                for r in mrl_trace:
                    s.submit(Req(*r))
                res = s.drain()
                runs.append((res, s.now, s.gaps_ms, s.swaps, s.hits))
            if runs[0] != runs[1]:
                mrl_ok = False
                print(f"  ff/max_run_len mismatch b{batch} mrl{mrl}")
    gate("fast-forward == stepwise under affinity max_run_len", mrl_ok)

    # ---- calendar event core == scan loop --------------------------------
    # The Rust server's default core holds future arrivals in a binary
    # heap keyed (arrival_s.to_bits(), submission seq); the scan loop is
    # the retained bit-identity reference. Same split here via heapq —
    # the calendar must be invisible in every output, including on
    # out-of-submission-order arrivals and equal-time ties (seq
    # tie-break reproduces scan mode's stable FIFO).
    cal_ok = True
    cal_traces = ff_traces + [
        [(0, 0, 128, 6, 0.04), (1, 1, 128, 6, 0.01), (2, 2, 128, 6, 0.04),
         (3, 0, 128, 6, 0.0), (4, 1, 128, 6, 0.02), (5, 2, 128, 6, 0.04)],
    ]
    for policy in ("fcfs", "affinity", "sjf"):
        for batch in (1, 4):
            for chunk in (None, 64):
                for trace in cal_traces:
                    runs = []
                    for cal in (True, False):
                        s = Server("1b", ["Q", "V"], 256, max_batch=batch,
                                   policy=policy, prefill_chunk=chunk,
                                   calendar=cal)
                        for r in trace:
                            s.submit(Req(*r))
                        res = s.drain()
                        runs.append((res, s.now, s.gaps_ms, s.swaps, s.hits))
                    if runs[0] != runs[1]:
                        cal_ok = False
                        print(f"  calendar mismatch {policy}/b{batch}/"
                              f"chunk{chunk}")
    gate("calendar event core == scan loop (results, clock, gaps, swaps)",
         cal_ok)

    # ---- continuous paged-KV mode ----------------------------------------
    # With the pool far above demand the page gate never fires, admission
    # order is untouched, and page bookkeeping has zero timing effect —
    # continuous mode must be bit-invisible next to lockstep.
    print("\n== continuous paged-KV mode ==")
    cont_ok = True
    for policy in ("fcfs", "affinity", "sjf"):
        for batch in (1, 4):
            for trace in cal_traces:
                runs = []
                for continuous in (False, True):
                    s = Server("1b", ["Q", "V"], 256, max_batch=batch,
                               policy=policy, continuous=continuous,
                               fast_forward=False)
                    for r in trace:
                        s.submit(Req(*r))
                    res = s.drain()
                    runs.append((res, s.now, s.gaps_ms, s.swaps, s.hits))
                    if continuous:
                        cont_ok &= s.preemptions == 0 \
                            and s.pool.allocs == s.pool.frees > 0 \
                            and s.pool.used == 0
                if runs[0] != runs[1]:
                    cont_ok = False
                    print(f"  continuous mismatch {policy}/b{batch}")
    gate("ample-capacity continuous bit-matches lockstep (+conservation)",
         cont_ok)

    def cont_backlog():
        s = Server("1b", ["Q", "V"], 128, max_batch=4, policy="fcfs",
                   continuous=True, kv_pool_pages=5, fast_forward=False)
        for i in range(8):
            s.submit(Req(i, 0, 128, 140, 0.0))
        return s, s.drain()

    sb1, rb1 = cont_backlog()
    sb2, rb2 = cont_backlog()
    gate("over-capacity backlog completes all 8 requests", len(rb1) == 8)
    gate("over-capacity backlog preempts (restart-from-prefill cost)",
         sb1.preemptions > 0 and sb1.preempted_tokens > 0,
         f"({sb1.preemptions} preemptions, {sb1.preempted_tokens} tokens)")
    gate("page conservation (allocs == frees, none held at drain)",
         sb1.pool.allocs == sb1.pool.frees and sb1.pool.used == 0,
         f"({sb1.pool.allocs} allocs)")
    gate("pool peak hits capacity", sb1.pool.peak == 5)
    gate("continuous backlog deterministic",
         rb1 == rb2 and sb1.now == sb2.now
         and sb1.preemptions == sb2.preemptions
         and sb1.preempted_tokens == sb2.preempted_tokens)

    # ---- cross-request KV prefix reuse -----------------------------------
    print("\n== KV prefix reuse on the paged pool ==")

    def prefix_serv(batch, pool_pages=None, chunk=None, policy="fcfs"):
        s = Server("1b", ["Q", "V"], 256, max_batch=batch, policy=policy,
                   prefill_chunk=chunk, continuous=True,
                   kv_pool_pages=pool_pages, fast_forward=False)
        s.register_preamble(0, [0xFEEDFACE])
        return s

    def pfx_conserved(s):
        template = sum(s.block_cycles) * s.n_layers
        return (s.prefix_cycles_saved + s.prefix_cycles_charged
                == s.prefix_admissions * template
                and s.prefix.interns == s.prefix.releases
                and s.prefix.nodes_created == s.prefix.nodes_freed
                and s.prefix.live_nodes() == 0
                and s.pool.allocs == s.pool.frees and s.pool.used == 0)

    # A registered-but-unused preamble must be bit-invisible: plain
    # requests on a preamble-bearing server == the PR 7 continuous run.
    inv_ok = True
    for batch in (1, 4):
        runs = []
        for register in (False, True):
            s = Server("1b", ["Q", "V"], 256, max_batch=batch,
                       policy="fcfs", continuous=True, fast_forward=False)
            if register:
                s.register_preamble(0, [0xFEEDFACE])
            for i in range(6):
                s.submit(Req(i, i % 2, 256, 12, 0.003 * i))
            res = s.drain()
            runs.append((res, s.now, s.gaps_ms, s.swaps, s.hits))
            if register:
                inv_ok &= s.prefix_admissions == 0 \
                    and s.prefix.interns == 0 and s.prefix.hit_blocks == 0
        inv_ok &= runs[0] == runs[1]
    gate("share-0 traffic bit-matches plain continuous mode", inv_ok)

    # A cold chain charges the full template: one preambled request is
    # bit-identical to one plain request (hits only change what is
    # skipped, never how the remainder is costed).
    cold_runs = []
    for pre in (None, 0):
        s = prefix_serv(2)
        s.submit(Req(0, 0, 256, 8, 0.0, preamble=pre))
        cold_runs.append((s.drain(), s.now))
    gate("cold chain bit-matches a plain request", cold_runs[0] == cold_runs[1])

    # Sibling two-block chains: the exact hit/miss/node ledger the Rust
    # integration test asserts (root shared, leaves private).
    s2b = Server("1b", ["Q", "V"], 256, max_batch=4, policy="fcfs",
                 continuous=True, fast_forward=False)
    s2b.register_preamble(0, [0xAB, 0x01])
    s2b.register_preamble(1, [0xAB, 0x02])
    for i in range(4):
        s2b.submit(Req(i, 0, 256, 16, 0.0, preamble=i % 2))
    r2b = s2b.drain()
    gate("sibling chains share the root: 5 hits / 3 misses / 3 nodes",
         len(r2b) == 4 and s2b.prefix.hit_blocks == 5
         and s2b.prefix.miss_blocks == 3 and s2b.prefix.nodes_created == 3
         and pfx_conserved(s2b),
         f"(hits {s2b.prefix.hit_blocks}, misses {s2b.prefix.miss_blocks}, "
         f"nodes {s2b.prefix.nodes_created})")

    # Preemption famine over preambled requests: re-interning on
    # re-admission keeps every ledger conserved.
    sfam = prefix_serv(4, pool_pages=7)
    for i in range(8):
        sfam.submit(Req(i, 0, 256, 96, 0.001 * i, preamble=0))
    rfam = sfam.drain()
    gate("preemption famine conserves refcounts/nodes/pages",
         len(rfam) == 8 and sfam.preemptions > 0
         and sfam.prefix_admissions > 8 and pfx_conserved(sfam),
         f"({sfam.preemptions} preemptions, "
         f"{sfam.prefix_admissions} prefix admissions)")

    # Chunked continuous preemption: a mid-prefill victim's finished
    # chunks are charged to preempted_tokens (the PR 8 undercount fix).
    # 16-token pages, pool 33: the resident (256 in) holds 17 pages and
    # needs its 18th exactly at generated == 16. A newcomer arriving
    # inside that 16th decode step admits into the last 16 free pages,
    # finishes exactly one 128-token chunk, and is then the LIFO victim
    # of the resident's growth — so preempted_tokens must be exactly 128
    # (the old code charged 0 for mid-prefill victims).
    def chunk_serv():
        return Server("1b", ["Q", "V"], 256, max_batch=2, policy="fcfs",
                      prefill_chunk=64, continuous=True, kv_page_tokens=16,
                      kv_pool_pages=33, fast_forward=False)
    marks = []
    for out in (15, 16):
        sp = chunk_serv()
        sp.submit(Req(0, 0, 256, out, 0.0))
        sp.drain()
        marks.append(sp.now)
    sck = chunk_serv()
    sck.submit(Req(0, 0, 256, 200, 0.0))
    sck.submit(Req(1, 0, 256, 32, 0.5 * (marks[0] + marks[1])))
    rck = sck.drain()
    gate("chunked continuous preemption charges prefill tokens",
         len(rck) == 2 and sck.preemptions == 1
         and sck.preempted_tokens == 128
         and sck.pool.allocs == sck.pool.frees and sck.pool.used == 0,
         f"({sck.preemptions} preemptions, {sck.preempted_tokens} tokens)")

    # Preamble library: prefix-closed chains (agreement at depth d implies
    # agreement at every shallower depth) with a genuinely shared root.
    lib_ok = True
    chains = preamble_library_chains(4, 2)
    for a in chains:
        for b in chains:
            agree = [i for i in range(min(len(a), len(b))) if a[i] == b[i]]
            lib_ok &= agree == list(range(len(agree)))
    lib_ok &= chains[0][0] == chains[1][0] and len(chains) == 4
    gate("preamble library chains are prefix-closed with shared roots",
         lib_ok)

    # Conservation fuzz: preambled mixes across policies x batch x chunk.
    pfz_ok = True
    lib4 = preamble_library_chains(4, 2)
    for policy in ("fcfs", "affinity", "sjf"):
        for batch in (2, 4):
            for chunk in (None, 128):
                s = Server("1b", ["Q", "V"], 256, max_batch=batch,
                           policy=policy, prefill_chunk=chunk,
                           continuous=True, fast_forward=False)
                for p, chain in enumerate(lib4):
                    s.register_preamble(p, chain)
                for i in range(16):
                    pre = None if i % 3 == 0 else i % 4
                    inp = 256 if i % 5 else 192  # off-template never shares
                    s.submit(Req(i, i % 2, inp, 6 + i % 9, 0.002 * i,
                                 preamble=pre))
                res = s.drain()
                ok = len(res) == 16 and s.prefix_admissions > 0 \
                    and pfx_conserved(s) \
                    and s.prefix.hit_blocks + s.prefix.miss_blocks \
                    >= s.prefix.interns
                s2x = Server("1b", ["Q", "V"], 256, max_batch=batch,
                             policy=policy, prefill_chunk=chunk,
                             continuous=True, fast_forward=False)
                for p, chain in enumerate(lib4):
                    s2x.register_preamble(p, chain)
                for i in range(16):
                    pre = None if i % 3 == 0 else i % 4
                    inp = 256 if i % 5 else 192
                    s2x.submit(Req(i, i % 2, inp, 6 + i % 9, 0.002 * i,
                                   preamble=pre))
                ok &= s2x.drain() == res and s2x.now == s.now
                pfz_ok &= ok
                if not ok:
                    print(f"  FAIL prefix fuzz {policy}/b{batch}/chunk{chunk}")
    gate("prefix fuzz conserves FLOPs/refcounts/pages and replays bitwise",
         pfz_ok)

    # Tail-latency payoff under near-saturation load (the integration
    # test's scenario): arrivals paced between the shared and plain
    # service rates make the plain queue grow without bound while the
    # fully shared run keeps up — the p95 arrival-to-first-token drop
    # must exceed the fraction of work removed (superlinear in hit rate).
    def probe_service(shared):
        s = prefix_serv(2)
        for i in range(2):
            s.submit(Req(i, 0, 256, 8, 0.0, preamble=0 if shared else None))
        assert len(s.drain()) == 2
        return s.now / 2.0

    def loaded_run(shared_n, gap):
        s = prefix_serv(2)
        for i in range(32):
            s.submit(Req(i, 0, 256, 8, i * gap,
                         preamble=0 if i < shared_n else None))
        res = s.drain()
        assert len(res) == 32
        ft = sorted(r["queue"] + r["ttft"] for r in res)
        return ft[min(max(math.ceil(0.95 * len(ft)), 1), len(ft)) - 1], s

    sp_plain = probe_service(False)
    sp_shared = probe_service(True)
    gap = 0.65 * sp_plain + 0.35 * sp_shared
    p95_plain, _ = loaded_run(0, gap)
    p95_half, _ = loaded_run(16, gap)
    p95_full, sfull = loaded_run(32, gap)
    drop_full = (p95_plain - p95_full) / p95_plain
    print(f"  p95 first-token: plain {p95_plain*1e3:.2f} ms, "
          f"half {p95_half*1e3:.2f} ms, full {p95_full*1e3:.2f} ms "
          f"(drop {drop_full*100:.1f}%)")
    gate("p95 first-token falls monotonically with the share",
         p95_full < p95_half < p95_plain)
    gate("full-share drop is superlinear (> 50% for half the prefill)",
         drop_full > 0.5 and sfull.prefix.hit_blocks > 0)

    # ---- heterogeneous batched engine ------------------------------------
    print("\n== heterogeneous batched engine (Table II --hetero) ==")
    het_ok = True
    for mdl in ("1b", "13b"):
        for ctx in (1024, 2048):
            uni = run_batched(mdl, ["Q", "V"], ctx, batch=4)
            het = hetero_cycles(mdl, ["Q", "V"], [ctx] * 4, ctx)
            if het != uni["cycles"]:
                het_ok = False
                print(f"  hetero collapse mismatch {mdl}/{ctx}: "
                      f"{het} != {uni['cycles']}")
    gate("equal prompts collapse to the uniform engine (u64 cycles)", het_ok)
    lo = run_batched("13b", ["Q", "V"], 512, batch=3, out_tokens=2048)
    hi = run_batched("13b", ["Q", "V"], 2048, batch=3, out_tokens=2048)
    mixed = hetero_cycles("13b", ["Q", "V"], [512, 1024, 2048], 2048)
    gate("mixed prompts land between the uniform bounds",
         lo["cycles"] < mixed < hi["cycles"],
         f"({lo['cycles']} < {mixed} < {hi['cycles']})")

    # ---- engine: batch-1 bit-match + batch-4 shape -----------------------
    print("\n== Simulator::run_batched checks (1B Q+V 1024) ==")
    b1 = run_batched("1b", ["Q", "V"], 1024, batch=1)
    b1b = run_batched("1b", ["Q", "V"], 1024, batch=1)
    gate("batch1 deterministic", b1 == b1b)
    b4 = run_batched("1b", ["Q", "V"], 1024, batch=4)
    gate("b4 throughput > 1.1x b1", b4["throughput"] > b1["throughput"] * 1.1,
         f"({b4['throughput']:.1f} vs {b1['throughput']:.1f})")
    gate("b4 throughput < 4x b1", b4["throughput"] < b1["throughput"] * 4.0)
    gate("b4 itl in (1, 2)x b1",
         b1["itl_ms"] < b4["itl_ms"] < 2.0 * b1["itl_ms"],
         f"({b4['itl_ms']:.3f} vs {b1['itl_ms']:.3f})")
    gate("b4 power > b1", b4["power"] > b1["power"],
         f"({b4['power']:.2f} vs {b1['power']:.2f})")
    gate("b4 efficiency > b1", b4["eff"] > b1["eff"],
         f"({b4['eff']:.1f} vs {b1['eff']:.1f})")
    gate("b4 energy > b1", b4["energy"] > b1["energy"])
    for mdl in ("1b", "8b", "13b"):
        for ctx in (1024, 2048):
            s1 = run_batched(mdl, ["Q", "V"], ctx, batch=1)
            s4 = run_batched(mdl, ["Q", "V"], ctx, batch=4)
            gate(f"{mdl}/{ctx} b4 tput above b1",
                 s4["throughput"] > s1["throughput"],
                 f"({s4['throughput']:.1f} vs {s1['throughput']:.1f})")

    # ---- serving: chunk >= prompt bit-matches monolithic ------------------
    print("\n== chunked prefill property checks (1B Q+V) ==")

    def run_server(ctx, batch, policy, chunk, trace, max_run_len=None,
                   fast_forward=True):
        s = Server("1b", ["Q", "V"], ctx, max_batch=batch, policy=policy,
                   prefill_chunk=chunk, max_run_len=max_run_len,
                   fast_forward=fast_forward)
        for r in trace:
            s.submit(Req(*r))
        res = s.drain()
        return s, res

    trace = [(0, 0, 256, 16, 0.0), (1, 1, 256, 16, 0.0), (2, 0, 128, 8, 0.0),
             (3, 1, 320, 12, 0.0)]
    _, mono = run_server(256, 1, "fcfs", None, trace)
    _, big = run_server(256, 1, "fcfs", 4096, trace)
    gate("chunk>=prompt bit-matches monolithic (batch1)",
         all(a["ttft"] == b["ttft"] and a["total"] == b["total"]
             and a["start"] == b["start"] for a, b in zip(mono, big)))
    _, small = run_server(256, 1, "fcfs", 128, trace)
    gate("batch1 chunked bit-matches monolithic",
         all(a["ttft"] == b["ttft"] and a["total"] == b["total"]
             and a["start"] == b["start"] for a, b in zip(mono, small)))
    _, c64 = run_server(256, 1, "fcfs", 64, trace)
    gate("prefill conserved across chunk sizes",
         all(a["ttft"] == b["ttft"] for a, b in zip(small, c64)))

    # ---- stall monotonicity ----------------------------------------------
    probe_s, probe = run_server(512, 1, "fcfs", None, [(0, 0, 512, 2, 0.0)])
    t_admit = probe[0]["ttft"] * 1.001  # B arrives just after A's prefill
    stalls = []
    for chunk in (None, 512, 256, 128):
        s, res = run_server(512, 2, "fcfs", chunk,
                            [(0, 0, 512, 2, 0.0), (1, 0, 512, 2, t_admit)])
        a = next(r for r in res if r["id"] == 0)
        stalls.append(a["stall"])
    print(f"  stalls by chunk [mono,512,256,128]: {[f'{x:.4f}' for x in stalls]}")
    gate("stall monotone non-increasing as chunk shrinks",
         all(stalls[i] >= stalls[i + 1] - 1e-15 for i in range(len(stalls) - 1)))
    gate("chunk 128 strictly reduces stall", stalls[-1] < stalls[0] * 0.999)

    # ---- serving_policies bench scenario ---------------------------------
    # Prefill-heavy mix (512-token prompts, 4-token outputs): the regime
    # the ISSUE motivates — admissions dominate, every monolithic prefill
    # stalls the whole in-flight batch. Decode-heavy mixes trade the other
    # way (continuous admission keeps more slots exposed); see DESIGN.md.
    print("\n== serving_policies chunked-vs-monolithic gate (the bench trace) ==")
    n_adapters, n_requests = 4, 24
    bench_trace = [(i, i % n_adapters, 512, 4, 0.0) for i in range(n_requests)]
    sm, rm = run_server(512, 4, "affinity", None, bench_trace)
    sc_, rc = run_server(512, 4, "affinity", 128, bench_trace)
    mean_stall_m = sum(r["stall"] for r in rm) / len(rm)
    mean_stall_c = sum(r["stall"] for r in rc) / len(rc)
    # Nearest-rank percentile, mirroring latency_stats' bugfixed
    # `ceil(q*n)` rank (the old `round((n-1)*q)` index sat one rank low
    # on small n: p50 of [a, b] returned b).
    pctl = lambda xs, q: \
        sorted(xs)[min(max(math.ceil(q * len(xs)), 1), len(xs)) - 1]
    gate("nearest-rank percentile small-n facts",
         pctl([3.0], 0.5) == 3.0 and pctl([2.0, 1.0], 0.5) == 1.0
         and pctl([3.0, 1.0, 2.0], 0.5) == 2.0
         and pctl([5.0, 4.0, 3.0, 2.0, 1.0], 0.95) == 5.0
         and pctl(list(range(1, 101)), 0.95) == 95)
    p95 = lambda xs: pctl(xs, 0.95)
    p95_itl_m = p95(sm.gaps_ms)
    p95_itl_c = p95(sc_.gaps_ms)
    print(f"  mean stall mono {mean_stall_m:.4f} s vs chunked {mean_stall_c:.4f} s")
    print(f"  p95 ITL   mono {p95_itl_m:.2f} ms vs chunked {p95_itl_c:.2f} ms")
    gate("chunked mean stall strictly below monolithic",
         mean_stall_c < mean_stall_m)
    gate("chunked p95 ITL strictly below monolithic", p95_itl_c < p95_itl_m)
    gate("same tokens served", sum(r["out"] for r in rm) == sum(r["out"] for r in rc))
    thr_m = sum(r["out"] + 512 for r in rm) / sm.now
    thr_c = sum(r["out"] + 512 for r in rc) / sc_.now
    print(f"  tok/s mono {thr_m:.1f} vs chunked {thr_c:.1f}")
    gate("chunked throughput within 10% of monolithic", thr_c > thr_m * 0.9)

    # ---- fuzz invariants --------------------------------------------------
    print("\n== randomized scheduling invariants ==")
    rng_state = [0x9E3779B97F4A7C15]

    def rnd(n):
        rng_state[0] = (rng_state[0] * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        return (rng_state[0] >> 33) % n

    ok_all = True
    for policy in ("fcfs", "affinity", "sjf"):
        for batch in (1, 4):
            for chunk in (None, 128):
                trace = []
                t = 0.0
                for i in range(12):
                    t += rnd(100) / 100.0
                    trace.append((i, rnd(3), 64 + rnd(256), 4 + rnd(20), t))
                s, res = run_server(256, batch, policy, chunk, trace)
                ok = len(res) == 12
                ok &= all(r["start"] >= r["arrival"] for r in res)
                ok &= all(r["queue"] >= 0 and r["stall"] >= -1e-15 for r in res)
                ok &= all(r["total"] >= r["ttft"] for r in res)
                for a, pa in s.per_adapter.items():
                    ok &= pa["swaps"] + pa["hits"] >= pa["served"] > 0 or pa["served"] == 0
                # determinism
                s2, res2 = run_server(256, batch, policy, chunk, trace)
                ok &= res == res2 and s.now == s2.now
                ok_all &= ok
                if not ok:
                    print(f"  FAIL {policy}/b{batch}/chunk{chunk}")
    gate("fuzz invariants (3 policies x 2 batch x 2 chunk)", ok_all)

    # ---- multi-chip sharding (PR 4) ---------------------------------------
    print("\n== sharded mapping checks (run_sharded + Table II Chips cells) ==")

    # 1-chip bit-match on ALL 12 grid points (the non-negotiable gate).
    bit_ok = True
    for mdl in ("1b", "8b", "13b"):
        for tg in (["Q"], ["Q", "V"]):
            for ctx in (1024, 2048):
                a = run_batched(mdl, tg, ctx, batch=1)
                c = run_batched(mdl, tg, ctx, batch=1, n_chips=1)
                bit_ok &= a == c
    gate("1-chip sharded bit-matches serial on all 12 grid points", bit_ok)

    # Sliced-program conservation (FLOP/byte classes partition exactly).
    cons_ok = True
    for mdl in ("8b", "13b"):
        lmx = map_model(mdl, ["Q", "V"])
        for prog in (decode_program(mdl, ["Q", "V"], lmx, 1536),
                     prefill_program(mdl, ["Q", "V"], lmx, 128, 512)):
            full = program_cost(prog)
            for n in (2, 4):
                tot = Cost()
                for chip in range(n):
                    tot._merge_events(program_cost(shard_program_slice(prog, chip, n)))
                cons_ok &= (tot.rram_passes == full.rram_passes
                            and tot.sram_passes == full.sram_passes
                            and tot.dmac_macs == full.dmac_macs
                            and tot.softmax_elems == full.softmax_elems
                            and tot.spad_bytes == full.spad_bytes
                            and tot.d2d_bytes == full.d2d_bytes * n)
    gate("sliced programs conserve FLOP/byte classes (chips 2,4)", cons_ok)

    # split_even exactness.
    se_ok = all(sum(split_even(t, n)) == t
                for t in (0, 7, 40, 65521, 2**32 - 1) for n in range(1, 10))
    gate("split_even partitions exactly", se_ok)

    # Per-chip KV footprint monotone non-increasing; all-reduce increasing.
    mono_ok = True
    for mdl in ("1b", "8b", "13b"):
        lmx = map_model(mdl, ["Q", "V"])
        for slots in (1, 4):
            feet = [shard_kv_bytes_per_router(lmx, n, 4096, slots)
                    for n in (1, 2, 4, 8)]
            mono_ok &= all(feet[i] >= feet[i + 1] for i in range(len(feet) - 1))
    gate("per-chip KV footprint monotone non-increasing", mono_ok)
    ar_ok = True
    for hidden in (2048, 4096, 5120):
        for tokens in (1, 128):
            costs = [layer_all_reduce_cycles(n, hidden, tokens)
                     for n in (2, 3, 4, 6, 8)]
            ar_ok &= all(costs[i] < costs[i + 1] for i in range(len(costs) - 1))
            ar_ok &= layer_all_reduce_cycles(1, hidden, tokens) == 0
    gate("all-reduce cost strictly increasing in chip count", ar_ok)

    # Sharded scaling shape on every grid point: 2 chips raise throughput
    # (within 2x), raise power, lower efficiency.
    shape_ok = True
    chips_rows = []
    for mdl in ("1b", "8b", "13b"):
        for tg in (["Q"], ["Q", "V"]):
            for ctx in (1024, 2048):
                s1 = run_batched(mdl, tg, ctx, batch=1)
                s2 = run_batched(mdl, tg, ctx, batch=1, n_chips=2)
                shape_ok &= s1["throughput"] < s2["throughput"] < 2 * s1["throughput"]
                shape_ok &= s2["power"] > s1["power"] and s2["eff"] < s1["eff"]
                chips_rows.append((mdl, "+".join(tg), ctx, 2, s2))
    gate("2-chip sharding: tput in (1,2)x, power up, efficiency down "
         "(all 12 points)", shape_ok)
    c4 = run_batched("1b", ["Q", "V"], 1024, batch=1, n_chips=4)
    c2 = run_batched("1b", ["Q", "V"], 1024, batch=1, n_chips=2)
    gate("4 chips beat 2 chips on 1B throughput",
         c4["throughput"] > c2["throughput"],
         f"({c4['throughput']:.1f} vs {c2['throughput']:.1f})")

    # 13B batch-4: KV-infeasible on 1 and 2 chips, opened at 4 chips, and
    # the sharded run beats the serial single-chip point.
    gate("13B/2048 b4 infeasible at 1 chip",
         not config_validate_kv("13b", ["Q", "V"], 2048, 4, 1))
    gate("13B/2048 b4 infeasible at 2 chips",
         not config_validate_kv("13b", ["Q", "V"], 2048, 4, 2))
    gate("13B/2048 b4 feasible at 4 chips",
         config_validate_kv("13b", ["Q", "V"], 2048, 4, 4))
    s13 = run_batched("13b", ["Q", "V"], 2048, batch=1)
    b4c4 = run_batched("13b", ["Q", "V"], 2048, batch=4, n_chips=4)
    gate("13B b4 over 4 chips beats serial throughput",
         b4c4["throughput"] > s13["throughput"],
         f"({b4c4['throughput']:.1f} vs {s13['throughput']:.1f})")
    chips_rows.append(("13b", "Q+V", 2048, 4, b4c4))

    # Sharded serving event loop: 1 chip is bit-identical to the default
    # server; 2 chips drain the same trace strictly faster.
    serve_trace = [(i, i % 3, 256, 8 + i, 0.0) for i in range(9)]

    def run_sharded_server(chips, batch, chunk):
        s = Server("1b", ["Q", "V"], 256, max_batch=batch, policy="fcfs",
                   prefill_chunk=chunk, n_chips=chips)
        for r in serve_trace:
            s.submit(Req(*r))
        return s, s.drain()

    sa, ra = run_sharded_server(1, 4, 128)
    s_dflt = Server("1b", ["Q", "V"], 256, max_batch=4, policy="fcfs",
                    prefill_chunk=128)
    for r in serve_trace:
        s_dflt.submit(Req(*r))
    rb = s_dflt.drain()
    gate("1-chip sharded server bit-matches default server",
         ra == rb and sa.now == s_dflt.now)
    s2_, _ = run_sharded_server(2, 4, 128)
    gate("2-chip server drains the trace strictly faster",
         s2_.now < sa.now, f"({s2_.now:.3f} vs {sa.now:.3f} s)")

    # The blessed Table II "Chips" cells (cross-check for the Rust bench).
    print("\n  Table II Chips cells (model/lora/ctx/chips: tok/s, W, tok/J):")
    for mdl, tg, ctx, n, s in chips_rows:
        print(f"    {mdl:>3} {tg:>3} {ctx:>4} c{n}: "
              f"{s['throughput']:8.2f} {s['power']:6.2f} {s['eff']:8.2f}")

    # ---- disaggregated pools ---------------------------------------------
    print("\n== disaggregated pools (run_disagg + overlapped serving) ==")
    # Degenerate collapse: a unified single-stage plan IS run_batched —
    # every report field (cycle integers and energy float bits) from the
    # identical operation sequence.
    coll = True
    for mdl, ctx in (("1b", 512), ("13b", 1024)):
        for ncx in (1, 3, 4):
            for sp in (True, False):
                a, _ = run_disagg(mdl, ["Q", "V"], ctx, batch=2, srpg=sp,
                                  n_chips=ncx, out_tokens=97)
                bref = run_batched(mdl, ["Q", "V"], ctx, batch=2, srpg=sp,
                                   n_chips=ncx, closed_form=False,
                                   out_tokens=97)
                coll = coll and a == bref
    gate("unified single-stage run_disagg == run_batched (all fields)", coll)
    # Pool-split conservation: the unsharded per-block instruction events
    # and the decode token-slot count are invariant across any split of
    # the same total chips; migration is strictly positive for >= 2 pools
    # and the ready staircase strictly increases across the batch.
    uref, uinfo = run_disagg("1b", ["Q", "V"], 512, batch=4, n_chips=4,
                             out_tokens=64)
    cons = mig = stair = True
    for split in ((1, 3), (2, 2), (3, 1)):
        _, info = run_disagg("1b", ["Q", "V"], 512, batch=4,
                             prefill_chips=split[0], decode_chips=split[1],
                             out_tokens=64)
        ue, se = uinfo["prefill_events"], info["prefill_events"]
        cons = cons and info["token_slots"] == 4 * 64 \
            and (se.dmac_macs, se.rram_passes, se.softmax_elems,
                 se.sram_passes) \
            == (ue.dmac_macs, ue.rram_passes, ue.softmax_elems,
                ue.sram_passes)
        mig = mig and info["migrate_cycles"] > 0
        stair = stair and all(info["ready"][i] < info["ready"][i + 1]
                              for i in range(3))
    gate("per-block events + token slots conserved across pool splits", cons)
    gate("KV migration strictly positive for >= 2 pools", mig)
    gate("prefill ready staircase strictly increasing", stair)
    gate("unified plan pays zero migration", uinfo["migrate_cycles"] == 0)
    # Pipeline packing: 2 stages over a 2-chip pool run each stage at
    # width 1, so the per-layer prefill cost equals the 1-chip cost, and
    # the stage split covers every layer exactly once.
    _, pinfo = run_disagg("1b", ["Q", "V"], 512, batch=2, prefill_chips=2,
                          decode_chips=2, stages=2, out_tokens=32)
    _, oinfo = run_disagg("1b", ["Q", "V"], 512, batch=2, n_chips=1,
                          out_tokens=32)
    gate("2-stage lpc == width-1 lpc (stage tensor group is the split)",
         pinfo["lpc"] == oinfo["lpc"])
    gate("stage layers cover the model exactly",
         sum(pool_stage_layers(MODELS["1b"]["layers"], 2))
         == MODELS["1b"]["layers"])
    # Serving: the Table II --disagg winning cell (witnesses blessed in
    # proxies_13b above — the Rust bench recomputes both serves).
    gate("Table II --disagg: 2p+2d beats symmetric 4-chip serving",
         px["disagg13b_2p2d_drain_ns"] < px["disagg13b_sym4_drain_ns"],
         f"({px['disagg13b_2p2d_drain_ns']} vs "
         f"{px['disagg13b_sym4_drain_ns']} ns)")
    # Single-request component identity: a disagg slot decodes at the
    # decode width — ITL bits equal a plain continuous serve at that
    # width — and its TTFT is exactly reprog + prefill-at-the-prefill-
    # width + the ChipMesh migration of the whole prompt's KV.
    def one_req(**kw):
        s = Server("1b", ["Q", "V"], 512, max_batch=1, policy="fcfs",
                   continuous=True, fast_forward=False, **kw)
        s.submit(Req(0, 0, 512, 64, 0.0))
        fin = s.drain()
        assert len(fin) == 1
        return s, fin[0]
    _, fd = one_req(prefill_chips=3, decode_chips=1)
    _, f1 = one_req(n_chips=1)
    sp3, _ = one_req(n_chips=3)
    mig_s = float(chip_transfer_cycles(
        512 * sp3.lm.kv_token_bytes * sp3.n_layers)) * CYCLE_S
    gate("disagg(3,1) ITL bits == 1-chip continuous ITL",
         fd["itl_ms"] == f1["itl_ms"])
    gate("disagg TTFT == reprog + prefill@3 + migration (bits)",
         fd["ttft"] == sp3.reprog_s + sp3.monolithic_prefill_s(512, 0)
         + mig_s)
    # KV pressure on the decode pool: an undersized pool preempts pending
    # (migrated, not yet joined) admissions too, and the page ledger
    # still conserves exactly.
    tight = Server("1b", ["Q", "V"], 256, max_batch=4, policy="fcfs",
                   continuous=True, fast_forward=False, prefill_chips=3,
                   decode_chips=1, kv_pool_pages=5)
    for i in range(6):
        tight.submit(Req(i, 0, 256, 200, 0.0))
    tfin = tight.drain()
    gate("undersized disagg pool serves the backlog via preemption",
         len(tfin) == 6 and tight.preemptions > 0,
         f"({tight.preemptions} preemptions)")
    gate("disagg page ledger conserves (allocs == frees, none live)",
         tight.pool.allocs == tight.pool.frees and tight.pool.used == 0)

    # ---- affinity starvation bound ---------------------------------------
    print("\n== affinity max_run_len starvation bound ==")
    star_trace = [(i, 0, 256, 8, 0.0) for i in range(8)] + [(8, 1, 256, 8, 0.0)]
    _, unbounded = run_server(256, 1, "affinity", None, star_trace)
    _, bounded = run_server(256, 1, "affinity", None, star_trace, max_run_len=2)
    pos_u = [r["id"] for r in unbounded].index(8)
    pos_b = [r["id"] for r in bounded].index(8)
    q_u = next(r for r in unbounded if r["id"] == 8)["queue"]
    q_b = next(r for r in bounded if r["id"] == 8)["queue"]
    print(f"  minority served at position {pos_u} (queue {q_u:.2f} s) unbounded, "
          f"{pos_b} (queue {q_b:.2f} s) bounded")
    gate("bounded affinity serves minority earlier", pos_b < pos_u and q_b < q_u)
    gate("unbounded affinity starves to the end", pos_u == len(star_trace) - 1)
    gate("bounded run length respected", pos_b <= 2)

    # ---- sweep costing cache (structural replay) -------------------------
    print("\n== sweep costing cache (structural replay of the bench grid) ==")
    sw_grid, (sw_cold, sw_warm1, sw_warm4) = sweepcache_replay()
    gate("grid is the bench's 12-point 1B sweep", len(sw_grid) == 12)
    gate("cold pass builds each shared artifact exactly once",
         sw_cold["mapping_builds"] == 1
         and sw_cold["layer_model_builds"] == 2
         and sw_cold["prefill_builds"] == 16
         and sw_cold["reprog_builds"] == 1
         and sw_cold["programs_generated"] == 37,
         "(1 mapping, 2 models, 16 prefill, 1 reprog, 37 programs)")
    gate("cold window memo: 6 inserts, 12 hits, no cap skips",
         sw_cold["window_hits"] == 12 and sw_cold["window_inserts"] == 6
         and sw_cold["window_full_skips"] == 0)
    gate("incremental rerun rebuilds nothing",
         sum(sw_warm1[k] + sw_warm4[k] for k in BUILD_FIELDS) == 0
         and sw_warm1["programs_generated"] + sw_warm4["programs_generated"] == 0
         and sw_warm1["window_inserts"] + sw_warm4["window_inserts"] == 0)
    gate("warm counters independent of worker width", sw_warm1 == sw_warm4)
    gate("warm pass is all hits (56 prefill / 18 model / 12 mapping lookups)",
         sw_warm1["prefill_hits"] == 56 and sw_warm1["layer_model_hits"] == 18
         and sw_warm1["mapping_hits"] == 12 and sw_warm1["reprog_hits"] == 12
         and sw_warm1["window_hits"] == 18)
    sweep_base = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "..", "..", "rust", "benches", "baselines",
                              "BENCH_sweep.json")
    if os.path.exists(sweep_base):
        with open(sweep_base) as f:
            committed = f.read()
        gate("committed BENCH_sweep.json matches the replay byte-for-byte",
             committed == sweepcache_json())
    else:
        gate("BENCH_sweep.json baseline present", False,
             f"(missing {sweep_base})")

    print()
    if failures:
        print(f"{len(failures)} FAILURES: {failures}")
        sys.exit(1)
    print("all mirror checks passed")


if __name__ == "__main__":
    main()
