"""L2 correctness: decoder layer (kernel path) vs pure-jnp oracle, shapes,
RoPE/RMSNorm properties, and manifest/artifact consistency."""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.LayerConfig(
    hidden=512, n_heads=8, n_kv_heads=8, head_dim=64,
    intermediate=1024, lora_rank=8, lora_targets=("q", "v"), kv_capacity=512,
)
GQA_CFG = dataclasses.replace(CFG, n_kv_heads=4, lora_targets=("q", "v"))


@pytest.fixture(scope="module")
def weights():
    return model.init_layer_weights(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def gqa_weights():
    return model.init_layer_weights(GQA_CFG, jax.random.PRNGKey(1))


def _decode_inputs(cfg, seed=2, hist=19):
    key = jax.random.PRNGKey(seed)
    kx, kk, kv = jax.random.split(key, 3)
    x = jax.random.normal(kx, (cfg.hidden,), jnp.float32)
    kc = jnp.zeros((cfg.kv_capacity, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:hist].set(
        jax.random.normal(kk, (hist, cfg.n_kv_heads, cfg.head_dim), jnp.float32))
    vc = vc.at[:hist].set(
        jax.random.normal(kv, (hist, cfg.n_kv_heads, cfg.head_dim), jnp.float32))
    return x, kc, vc, jnp.int32(hist)


class TestDecodeStep:
    def test_matches_ref(self, weights):
        x, kc, vc, pos = _decode_inputs(CFG)
        y, kn, vn = model.decode_step(CFG, weights, x, kc, vc, pos)
        yr, knr, vnr = model.decode_step_ref(CFG, weights, x, kc, vc, pos)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(kn), np.asarray(knr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(vn), np.asarray(vnr),
                                   rtol=1e-4, atol=1e-4)

    def test_shapes(self, weights):
        x, kc, vc, pos = _decode_inputs(CFG)
        y, kn, vn = model.decode_step(CFG, weights, x, kc, vc, pos)
        assert y.shape == (CFG.hidden,)
        assert kn.shape == (CFG.n_kv_heads, CFG.head_dim)
        assert vn.shape == (CFG.n_kv_heads, CFG.head_dim)

    def test_gqa_matches_ref(self, gqa_weights):
        x, kc, vc, pos = _decode_inputs(GQA_CFG, seed=3)
        y, _, _ = model.decode_step(GQA_CFG, gqa_weights, x, kc, vc, pos)
        yr, _, _ = model.decode_step_ref(GQA_CFG, gqa_weights, x, kc, vc, pos)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-3)

    def test_position_zero(self, weights):
        """First decode token: empty history, attends only to itself."""
        x, kc, vc, _ = _decode_inputs(CFG, hist=0)
        y, _, _ = model.decode_step(CFG, weights, x, kc, vc, jnp.int32(0))
        assert np.isfinite(np.asarray(y)).all()

    def test_lora_changes_output(self, weights):
        """Swapping in a different adapter changes the layer output."""
        x, kc, vc, pos = _decode_inputs(CFG)
        y1, _, _ = model.decode_step(CFG, weights, x, kc, vc, pos)
        w2 = weights._replace(
            lora_q=model.LoraPair(weights.lora_q.a * 2.0, weights.lora_q.b))
        y2, _, _ = model.decode_step(CFG, w2, x, kc, vc, pos)
        assert np.abs(np.asarray(y1) - np.asarray(y2)).max() > 1e-4


class TestPrefillBlock:
    def test_shapes(self, weights):
        t = 16
        x = jax.random.normal(jax.random.PRNGKey(4), (t, CFG.hidden), jnp.float32)
        y, kb, vb = model.prefill_block(CFG, weights, x, jnp.int32(0))
        assert y.shape == (t, CFG.hidden)
        assert kb.shape == (t, CFG.n_kv_heads, CFG.head_dim)
        assert vb.shape == (t, CFG.n_kv_heads, CFG.head_dim)

    def test_prefill_then_decode_consistent(self, weights):
        """Decode right after prefill sees the prefill K/V via the cache."""
        t = 8
        x = jax.random.normal(jax.random.PRNGKey(5), (t, CFG.hidden), jnp.float32)
        _, kb, vb = model.prefill_block(CFG, weights, x, jnp.int32(0))
        kc = jnp.zeros((CFG.kv_capacity, CFG.n_kv_heads, CFG.head_dim))
        vc = jnp.zeros_like(kc)
        kc = kc.at[:t].set(kb)
        vc = vc.at[:t].set(vb)
        xd = jax.random.normal(jax.random.PRNGKey(6), (CFG.hidden,), jnp.float32)
        y, _, _ = model.decode_step(CFG, weights, xd, kc, vc, jnp.int32(t))
        yr, _, _ = model.decode_step_ref(CFG, weights, xd, kc, vc, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-3)


class TestBuildingBlocks:
    def test_rms_norm_unit_scale(self):
        x = jnp.full((1, 64), 3.0)
        out = model.rms_norm(x, jnp.ones(64), 1e-6)
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4)

    def test_rope_preserves_norm(self):
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (5, 4, 64), jnp.float32)
        cos, sin = model.rope_tables(jnp.arange(5), 64, 500000.0)
        y = model.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)

    def test_rope_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n (per 2D subspace)."""
        d = 64
        key = jax.random.PRNGKey(8)
        q = jax.random.normal(key, (1, 1, d), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(9), (1, 1, d), jnp.float32)

        def dot_at(m, n):
            cm, sm = model.rope_tables(jnp.array([m]), d, 500000.0)
            cn, sn = model.rope_tables(jnp.array([n]), d, 500000.0)
            qm = model.apply_rope(q, cm, sm)[0, 0]
            kn = model.apply_rope(k, cn, sn)[0, 0]
            return float(jnp.dot(qm, kn))

        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3

    def test_repeat_kv(self):
        x = jnp.arange(2 * 2 * 3, dtype=jnp.float32).reshape(2, 2, 3)
        y = model._repeat_kv(x, 2)
        assert y.shape == (2, 4, 3)
        np.testing.assert_array_equal(np.asarray(y[:, 0]), np.asarray(y[:, 1]))


class TestArtifacts:
    """Consistency of the emitted artifacts (requires `make artifacts`)."""

    @pytest.fixture(scope="class")
    def manifest(self):
        p = pathlib.Path(__file__).resolve().parents[2] / "artifacts/manifest.json"
        if not p.exists():
            pytest.skip("artifacts not built (run `make artifacts`)")
        return json.loads(p.read_text()), p.parent

    def test_modules_present(self, manifest):
        m, root = manifest
        for mod in ("decode_step", "prefill_block", "lora_matmul"):
            assert mod in m["modules"]
            assert (root / m["modules"][mod]["hlo"]).exists()

    def test_tensor_files_match_manifest(self, manifest):
        m, root = manifest
        dtype_size = {"float32": 4, "int8": 1, "int32": 4}
        for mod in m["modules"].values():
            for entry in mod["params"] + mod["outputs"]:
                f = root / entry["file"]
                assert f.exists(), entry["file"]
                n = int(np.prod(entry["shape"])) if entry["shape"] else 1
                assert f.stat().st_size == n * dtype_size[entry["dtype"]]

    def test_golden_decode_output_reproducible(self, manifest):
        """Re-running the jitted decode on the stored inputs reproduces the
        stored outputs bit-for-bit (the Rust runtime relies on this)."""
        m, root = manifest
        mod = m["modules"]["decode_step"]

        def load(entry):
            a = np.fromfile(root / entry["file"], dtype=entry["dtype"])
            return jnp.asarray(a.reshape(entry["shape"]))

        leaves = [load(e) for e in mod["params"]]
        cfg = model.LayerConfig(**{
            k: tuple(v) if isinstance(v, list) else v
            for k, v in m["config"].items()})
        treedef = jax.tree_util.tree_structure(
            (model.init_layer_weights(cfg, jax.random.PRNGKey(0)),
             jnp.zeros(cfg.hidden),
             jnp.zeros((cfg.kv_capacity, cfg.n_kv_heads, cfg.head_dim)),
             jnp.zeros((cfg.kv_capacity, cfg.n_kv_heads, cfg.head_dim)),
             jnp.int32(0)))
        w, x, kc, vc, pos = jax.tree_util.tree_unflatten(treedef, leaves)
        y, kn, vn = model.jitted_decode_step(cfg)(w, x, kc, vc, pos)
        outs = [np.asarray(t) for t in (y, kn, vn)]
        for got, entry in zip(outs, mod["outputs"]):
            want = np.fromfile(root / entry["file"], dtype=entry["dtype"])
            np.testing.assert_allclose(
                got.ravel(), want, rtol=1e-5, atol=1e-5)
