"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

These are the core numerical-contract tests: hypothesis sweeps over
shapes/ranks/seeds for the PE-pair crossbar kernel, and over head counts /
KV lengths for the DMAC attention kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import KV_BLOCK, dmac_attention
from compile.kernels.lora_matmul import pim_lora_matmul, pim_matmul

jax.config.update("jax_enable_x64", False)


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape), jnp.float32) * scale


# --------------------------------------------------------------------------
# quantization primitives
# --------------------------------------------------------------------------

class TestQuantization:
    def test_weight_tiles_roundtrip_small_error(self):
        rng = np.random.default_rng(0)
        w = _rand(rng, 512, 768)
        wq, sc = ref.quantize_weight_tiles(w)
        assert wq.dtype == jnp.int8
        assert sc.shape == (2, 3)
        deq = np.asarray(wq, np.float32).reshape(2, 256, 3, 256) * np.asarray(
            sc
        )[:, None, :, None]
        err = np.abs(deq.reshape(512, 768) - np.asarray(w))
        # round-to-nearest error is bounded by scale/2 per tile
        bound = np.repeat(np.repeat(np.asarray(sc) / 2, 256, 0), 256, 1)
        assert (err <= bound + 1e-6).all()

    def test_weight_tiles_all_zero_tile(self):
        w = jnp.zeros((256, 512))
        wq, sc = ref.quantize_weight_tiles(w)
        assert np.all(np.asarray(wq) == 0)
        assert np.all(np.isfinite(np.asarray(sc)))

    def test_weight_tiles_rejects_untiled(self):
        with pytest.raises(AssertionError):
            ref.quantize_weight_tiles(jnp.zeros((100, 256)))

    def test_quantize_symmetric_range(self):
        rng = np.random.default_rng(1)
        t = _rand(rng, 64, scale=10.0)
        q = ref.quantize_i8(t, ref.symmetric_scale(t))
        assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_symmetric_scale_never_zero(self, seed):
        rng = np.random.default_rng(seed)
        t = _rand(rng, 16, scale=rng.uniform(0, 2))
        s = ref.symmetric_scale(t)
        assert float(s) > 0


# --------------------------------------------------------------------------
# PE-pair kernel: crossbar SMAC + LoRA
# --------------------------------------------------------------------------

class TestPimLoraMatmul:
    @given(
        t=st.sampled_from([1, 3, 8]),
        n_kt=st.integers(1, 3),
        n_mt=st.integers(1, 3),
        r=st.sampled_from([1, 4, 8, 16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=12, deadline=None)
    def test_matches_ref(self, t, n_kt, n_mt, r, seed):
        rng = np.random.default_rng(seed)
        k, m = 256 * n_kt, 256 * n_mt
        x = _rand(rng, t, k)
        w = _rand(rng, m, k, scale=1.0 / np.sqrt(k))
        wq, sc = ref.quantize_weight_tiles(w)
        a = _rand(rng, r, k, scale=0.05)
        b = _rand(rng, m, r, scale=0.05)
        got = pim_lora_matmul(x, wq, sc, a, b)
        want = ref.pim_lora_matmul_ref(x, wq, sc, a, b)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
        )

    def test_zero_lora_equals_plain(self):
        rng = np.random.default_rng(7)
        x = _rand(rng, 2, 512)
        w = _rand(rng, 256, 512, scale=0.05)
        wq, sc = ref.quantize_weight_tiles(w)
        got = pim_matmul(x, wq, sc)
        want = ref.pim_matmul_ref(x, wq, sc)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
        )

    def test_lora_path_contributes(self):
        """The SRAM-DCIM path must actually change the output."""
        rng = np.random.default_rng(8)
        x = _rand(rng, 1, 256)
        w = _rand(rng, 256, 256, scale=0.05)
        wq, sc = ref.quantize_weight_tiles(w)
        a = _rand(rng, 8, 256, scale=0.3)
        b = _rand(rng, 256, 8, scale=0.3)
        with_lora = np.asarray(pim_lora_matmul(x, wq, sc, a, b))
        without = np.asarray(pim_matmul(x, wq, sc))
        assert np.abs(with_lora - without).max() > 0.1

    def test_quantization_error_bounded(self):
        """Crossbar output must track the float matmul within int8 error."""
        rng = np.random.default_rng(9)
        x = _rand(rng, 4, 512)
        w = _rand(rng, 512, 512, scale=1.0 / np.sqrt(512))
        wq, sc = ref.quantize_weight_tiles(w)
        got = np.asarray(pim_matmul(x, wq, sc))
        exact = np.asarray(x) @ np.asarray(w).T
        rel = np.abs(got - exact).max() / np.abs(exact).max()
        assert rel < 0.05, f"quantization error too large: {rel}"

    def test_adc_quantization_monotone(self):
        """Fewer ADC bits => more error; many bits ~ exact read-out."""
        rng = np.random.default_rng(10)
        x = _rand(rng, 2, 512)
        w = _rand(rng, 256, 512, scale=0.05)
        wq, sc = ref.quantize_weight_tiles(w)
        exact = np.asarray(ref.pim_matmul_ref(x, wq, sc))
        errs = []
        for bits in (6, 8, 12, 24):
            approx = np.asarray(ref.pim_matmul_ref(x, wq, sc, adc_bits=bits))
            errs.append(np.abs(approx - exact).max())
        assert errs[0] >= errs[1] >= errs[2] >= errs[3]
        assert errs[-1] < 1e-3


# --------------------------------------------------------------------------
# DMAC attention kernel
# --------------------------------------------------------------------------

class TestDmacAttention:
    @given(
        h=st.sampled_from([1, 4, 8]),
        d=st.sampled_from([64, 128]),
        n_blk=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, h, d, n_blk, seed):
        rng = np.random.default_rng(seed)
        s = KV_BLOCK * n_blk
        kv_len = int(rng.integers(1, s + 1))
        q = _rand(rng, h, d)
        k = _rand(rng, s, h, d)
        v = _rand(rng, s, h, d)
        got = dmac_attention(q, k, v, kv_len)
        want = ref.dmac_attention_ref(q, k, v, kv_len)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )

    def test_kv_len_one(self):
        """Degenerate cache: output == v[0]."""
        rng = np.random.default_rng(3)
        q = _rand(rng, 4, 64)
        k = _rand(rng, KV_BLOCK, 4, 64)
        v = _rand(rng, KV_BLOCK, 4, 64)
        got = np.asarray(dmac_attention(q, k, v, 1))
        np.testing.assert_allclose(got, np.asarray(v[0]), rtol=1e-5, atol=1e-6)

    def test_masked_tail_is_ignored(self):
        """Garbage beyond kv_len must not affect the output."""
        rng = np.random.default_rng(4)
        q = _rand(rng, 4, 64)
        k = _rand(rng, 2 * KV_BLOCK, 4, 64)
        v = _rand(rng, 2 * KV_BLOCK, 4, 64)
        kv_len = 100
        a = np.asarray(dmac_attention(q, k, v, kv_len))
        k2 = k.at[kv_len:].set(1e4)
        v2 = v.at[kv_len:].set(-1e4)
        b = np.asarray(dmac_attention(q, k2, v2, kv_len))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_softmax_weights_are_convex(self):
        """Output lies in the convex hull of the values (per head/dim)."""
        rng = np.random.default_rng(5)
        q = _rand(rng, 2, 64)
        k = _rand(rng, KV_BLOCK, 2, 64)
        v = _rand(rng, KV_BLOCK, 2, 64)
        kv_len = 50
        out = np.asarray(dmac_attention(q, k, v, kv_len))
        vv = np.asarray(v[:kv_len])
        assert (out <= vv.max(axis=0) + 1e-5).all()
        assert (out >= vv.min(axis=0) - 1e-5).all()

    def test_prefill_ref_causality(self):
        """Changing a later token never affects an earlier output row."""
        rng = np.random.default_rng(6)
        t, h, d = 8, 2, 64
        q = _rand(rng, t, h, d)
        k = _rand(rng, t, h, d)
        v = _rand(rng, t, h, d)
        base = np.asarray(ref.dmac_attention_prefill_ref(q, k, v))
        k2 = k.at[-1].set(100.0)
        v2 = v.at[-1].set(-100.0)
        pert = np.asarray(ref.dmac_attention_prefill_ref(q, k2, v2))
        np.testing.assert_allclose(base[:-1], pert[:-1], rtol=1e-6, atol=1e-6)
        assert np.abs(base[-1] - pert[-1]).max() > 1e-3
